//! Preallocated id-indexed storage for the hot path (ROADMAP item 2).
//!
//! The coding tier keys everything by monotonically increasing u64 ids
//! (group ids, query ids). `std::collections::HashMap` serves those keys
//! correctly but expensively: SipHash per probe, per-entry heap boxes,
//! and no way to recycle the `Vec`s inside evicted values. [`ProbeMap`]
//! is the replacement index — an open-addressed linear-probe table from
//! `u64` keys to small `Copy` values (slot numbers, counters) with
//! backward-shift deletion, a splitmix64 finalizer for the hash, and no
//! per-entry allocation. Slab owners (e.g. `GroupTracker`'s group arena)
//! pair it with a free-listed `Vec` of recycled value bodies so the
//! steady-state cost of open/close is two array writes and a probe.

use std::fmt;

const EMPTY: u64 = u64::MAX;

#[inline]
fn mix(key: u64) -> u64 {
    // splitmix64 finalizer: cheap, and strong enough that sequential ids
    // spread uniformly across the table.
    let mut z = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Open-addressed `u64 -> V` map for hot-path bookkeeping. Keys must be
/// `< u64::MAX` (that value is the empty sentinel) — all ids in this
/// crate count up from 0, so the constraint is a debug assertion, not a
/// real restriction.
pub struct ProbeMap<V> {
    keys: Vec<u64>,
    vals: Vec<V>,
    len: usize,
}

impl<V: Copy + Default> Default for ProbeMap<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V: Copy + Default> ProbeMap<V> {
    pub fn new() -> Self {
        Self::with_capacity(16)
    }

    /// Table sized for `n` entries without growing (rounded up to a
    /// power of two at 3/4 load).
    pub fn with_capacity(n: usize) -> Self {
        let cap = (n.max(4) * 4 / 3 + 1).next_power_of_two();
        ProbeMap { keys: vec![EMPTY; cap], vals: vec![V::default(); cap], len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn mask(&self) -> usize {
        self.keys.len() - 1
    }

    /// Index of `key`, if present.
    #[inline]
    fn find(&self, key: u64) -> Option<usize> {
        let mask = self.mask();
        let mut i = (mix(key) as usize) & mask;
        loop {
            let k = self.keys[i];
            if k == key {
                return Some(i);
            }
            if k == EMPTY {
                return None;
            }
            i = (i + 1) & mask;
        }
    }

    pub fn contains_key(&self, key: u64) -> bool {
        self.find(key).is_some()
    }

    pub fn get(&self, key: u64) -> Option<V> {
        self.find(key).map(|i| self.vals[i])
    }

    pub fn get_mut(&mut self, key: u64) -> Option<&mut V> {
        self.find(key).map(|i| &mut self.vals[i])
    }

    /// Insert, returning the previous value if the key was present.
    pub fn insert(&mut self, key: u64, val: V) -> Option<V> {
        debug_assert!(key != EMPTY, "u64::MAX is the empty sentinel");
        if (self.len + 1) * 4 > self.keys.len() * 3 {
            self.grow();
        }
        let mask = self.mask();
        let mut i = (mix(key) as usize) & mask;
        loop {
            let k = self.keys[i];
            if k == key {
                let old = self.vals[i];
                self.vals[i] = val;
                return Some(old);
            }
            if k == EMPTY {
                self.keys[i] = key;
                self.vals[i] = val;
                self.len += 1;
                return None;
            }
            i = (i + 1) & mask;
        }
    }

    /// Remove via backward-shift deletion (no tombstones: probe chains
    /// stay short forever, which matters for a table that turns over
    /// once per coding group).
    pub fn remove(&mut self, key: u64) -> Option<V> {
        let mut i = self.find(key)?;
        let removed = self.vals[i];
        let mask = self.mask();
        self.keys[i] = EMPTY;
        let mut j = i;
        loop {
            j = (j + 1) & mask;
            let kj = self.keys[j];
            if kj == EMPTY {
                break;
            }
            let home = (mix(kj) as usize) & mask;
            // Skip entries whose home slot lies cyclically in (i, j] —
            // moving them into the hole would strand them before their
            // probe chain starts.
            let in_gap = if i < j { i < home && home <= j } else { home > i || home <= j };
            if !in_gap {
                self.keys[i] = kj;
                self.vals[i] = self.vals[j];
                self.keys[j] = EMPTY;
                i = j;
            }
        }
        self.len -= 1;
        Some(removed)
    }

    pub fn iter(&self) -> impl Iterator<Item = (u64, V)> + '_ {
        self.keys
            .iter()
            .zip(self.vals.iter())
            .filter(|(k, _)| **k != EMPTY)
            .map(|(k, v)| (*k, *v))
    }

    pub fn clear(&mut self) {
        self.keys.iter_mut().for_each(|k| *k = EMPTY);
        self.len = 0;
    }

    fn grow(&mut self) {
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; 0]);
        let old_vals = std::mem::take(&mut self.vals);
        let cap = (old_keys.len() * 2).max(8);
        self.keys = vec![EMPTY; cap];
        self.vals = vec![V::default(); cap];
        self.len = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY {
                self.insert(k, v);
            }
        }
    }
}

impl<V: Copy + Default + fmt::Debug> fmt::Debug for ProbeMap<V> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_map().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut m: ProbeMap<u32> = ProbeMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(7, 70), None);
        assert_eq!(m.insert(7, 71), Some(70));
        assert_eq!(m.get(7), Some(71));
        assert!(m.contains_key(7));
        assert_eq!(m.remove(7), Some(71));
        assert_eq!(m.remove(7), None);
        assert!(m.get(7).is_none());
    }

    #[test]
    fn get_mut_updates_in_place() {
        let mut m: ProbeMap<u32> = ProbeMap::new();
        m.insert(3, 1);
        *m.get_mut(3).unwrap() += 41;
        assert_eq!(m.get(3), Some(42));
        assert!(m.get_mut(4).is_none());
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut m: ProbeMap<u64> = ProbeMap::with_capacity(4);
        for i in 0..1000u64 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(i), Some(i * 2), "key {i}");
        }
    }

    #[test]
    fn backward_shift_keeps_probe_chains_intact() {
        // Dense sequential keys force long shared probe chains; deleting
        // from the middle must not orphan later chain members.
        let mut m: ProbeMap<u32> = ProbeMap::with_capacity(8);
        for i in 0..64u64 {
            m.insert(i, i as u32);
        }
        for i in (0..64u64).step_by(2) {
            assert_eq!(m.remove(i), Some(i as u32));
        }
        for i in 0..64u64 {
            let want = if i % 2 == 0 { None } else { Some(i as u32) };
            assert_eq!(m.get(i), want, "key {i}");
        }
    }

    #[test]
    fn randomized_against_std_hashmap() {
        let mut rng = Pcg64::new(0xA12E_7A);
        let mut ours: ProbeMap<u32> = ProbeMap::new();
        let mut reference: HashMap<u64, u32> = HashMap::new();
        for step in 0..20_000u32 {
            let key = rng.below(512) as u64;
            match rng.below(3) {
                0 => {
                    assert_eq!(
                        ours.insert(key, step),
                        reference.insert(key, step),
                        "insert {key} at step {step}"
                    );
                }
                1 => {
                    assert_eq!(
                        ours.remove(key),
                        reference.remove(&key),
                        "remove {key} at step {step}"
                    );
                }
                _ => {
                    assert_eq!(
                        ours.get(key),
                        reference.get(&key).copied(),
                        "get {key} at step {step}"
                    );
                }
            }
            assert_eq!(ours.len(), reference.len());
        }
        let mut got: Vec<(u64, u32)> = ours.iter().collect();
        got.sort_unstable();
        let mut want: Vec<(u64, u32)> = reference.into_iter().collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}

//! Blocking MPMC queue substrate (crossbeam-channel is not in the build
//! image; std::sync::mpsc receivers cannot be shared).
//!
//! This is the paper's "single queue" (§5.1 Load balancing): the frontend
//! pushes query batches, idle model instances pop them. Also used for the
//! parity queue. Mutex + Condvar is entirely adequate at
//! prediction-serving rates (thousands of ops/sec against
//! millisecond-scale service times). Two hot-path details:
//! * `len()` reads a lock-free counter, because the frontend publishes
//!   `backlog()` (a sum over every pool queue) on every admit decision —
//!   taking every queue's mutex per submit was measurable contention;
//! * all lock/wait sites recover from poisoning via
//!   [`crate::util::sync`], so one panicking worker never cascades into
//!   the other consumers of its queue.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::util::sync::{CondvarExt, LockExt};

struct Inner<T> {
    q: Mutex<State<T>>,
    cv: Condvar,
    /// Mirror of `items.len()`, maintained under the lock but readable
    /// without it.
    len: AtomicUsize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Shared handle: clone freely across producers and consumers.
pub struct Queue<T>(Arc<Inner<T>>);

impl<T> Clone for Queue<T> {
    fn clone(&self) -> Self {
        Queue(self.0.clone())
    }
}

impl<T> Default for Queue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Queue<T> {
    pub fn new() -> Self {
        Queue(Arc::new(Inner {
            q: Mutex::new(State { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            len: AtomicUsize::new(0),
        }))
    }

    /// Push an item. Returns Err(item) if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.0.q.plock();
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        self.0.len.store(st.items.len(), Ordering::Release);
        drop(st);
        self.0.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; None once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.0.q.plock();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.0.len.store(st.items.len(), Ordering::Release);
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.0.cv.pwait(st);
        }
    }

    /// Pop with a timeout; None on timeout or closed-and-drained.
    pub fn pop_timeout(&self, dur: Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.0.q.plock();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.0.len.store(st.items.len(), Ordering::Release);
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self.0.cv.pwait_timeout(st, deadline - now);
            st = g;
            if res.timed_out() && st.items.is_empty() {
                return None;
            }
        }
    }

    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.0.q.plock();
        let item = st.items.pop_front();
        if item.is_some() {
            self.0.len.store(st.items.len(), Ordering::Release);
        }
        item
    }

    /// Lock-free queue depth (mirror counter; exact at quiescence,
    /// momentarily stale under concurrent push/pop — fine for the
    /// admission and balancing heuristics that read it).
    pub fn len(&self) -> usize {
        self.0.len.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: wakes all blocked consumers; further pushes fail.
    pub fn close(&self) {
        self.0.q.plock().closed = true;
        self.0.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.0.q.plock().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = Queue::new();
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn close_drains_then_none() {
        let q = Queue::new();
        q.push(1).unwrap();
        q.close();
        assert!(q.push(2).is_err());
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn multi_consumer_receives_all() {
        let q: Queue<u32> = Queue::new();
        let n = 1000u32;
        let mut handles = Vec::new();
        for _ in 0..4 {
            let qc = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = qc.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for i in 0..n {
            q.push(i).unwrap();
        }
        q.close();
        let mut all: Vec<u32> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn pop_timeout_times_out() {
        let q: Queue<u32> = Queue::new();
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(20)), None);
        assert!(t0.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn pop_blocks_until_push() {
        let q: Queue<u32> = Queue::new();
        let qc = q.clone();
        let h = std::thread::spawn(move || qc.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.push(7).unwrap();
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn len_tracks_through_mixed_ops() {
        let q: Queue<u32> = Queue::new();
        for i in 0..10 {
            q.push(i).unwrap();
        }
        assert_eq!(q.try_pop(), Some(0));
        assert_eq!(q.len(), 9);
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Some(1));
        assert_eq!(q.len(), 8);
        for _ in 0..8 {
            q.pop();
        }
        assert!(q.is_empty());
        assert!(q.try_pop().is_none());
        assert_eq!(q.len(), 0);
    }
}

//! Minimal-but-complete JSON substrate (serde is not in the build image).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serializes experiment results. Supports the full JSON grammar
//! including unicode escapes; numbers are kept as f64 (adequate for the
//! manifest's integer fields, all < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---------------------------------------------------------- accessors --
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------ builders --
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// --------------------------------------------------------------- writer ----
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// --------------------------------------------------------------- parser ----
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            // Python's json may emit these for inf/nan (we also accept them
            // because aot.py records NaN train metrics for r>1 parities).
            Some(b'N') => self.literal("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.literal("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("expected a value")),
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                return self.literal("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let combined = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(), Some("c"));
        assert_eq!(v.at(&["d"]), &Json::Null);
        assert_eq!(v.at(&["missing", "deep"]), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn writes_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn python_nan_inf() {
        let v = Json::parse(r#"{"m": NaN, "p": Infinity, "n": -Infinity}"#).unwrap();
        assert!(v.at(&["m"]).as_f64().unwrap().is_nan());
        assert_eq!(v.at(&["p"]).as_f64().unwrap(), f64::INFINITY);
        assert_eq!(v.at(&["n"]).as_f64().unwrap(), f64::NEG_INFINITY);
    }

    #[test]
    fn rejects_garbage() {
        for t in ["", "{", "[1,", "{\"a\"}", "tru", "1 2"] {
            assert!(Json::parse(t).is_err(), "{t:?} should fail");
        }
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .set("name", "fig6")
            .set("k", 2usize)
            .set("vals", vec![1.0, 2.0]);
        let txt = v.to_string();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.at(&["name"]).as_str(), Some("fig6"));
        assert_eq!(back.at(&["vals"]).as_arr().unwrap().len(), 2);
    }
}

//! Sharded MPSC completion bus (ROADMAP item 2).
//!
//! The session's completion stream used to be one `std::sync::mpsc`
//! channel: every worker across every pool funneled into a single
//! internal mutex, and the session folded the backlog one `try_recv` at
//! a time. This bus shards the producer side — each sender is pinned
//! round-robin to one of N slots, so workers on different shards never
//! contend on the same lock — and the consumer sweeps a whole shard per
//! lock acquisition, swapping the filled `Vec` for an empty spare so a
//! burst of completions is folded in one pass with zero allocation at
//! steady state.
//!
//! Semantics match the mpsc channel the session grew up on:
//! * senders are cheap to clone; dropping the last one disconnects the
//!   bus (the receiver observes [`RecvStatus::Disconnected`] once
//!   drained), which is how `drain()` learns every pool is gone;
//! * dropping the receiver makes `send` return `Err(item)`, which is the
//!   worker loop's exit signal;
//! * all locks recover from poisoning ([`LockExt`]) so a panicking
//!   worker cannot cascade into the dispatcher.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::util::sync::{CondvarExt, LockExt};

struct Inner<T> {
    shards: Vec<Mutex<Vec<T>>>,
    /// Items pushed and not yet drained (advisory; exact under locks).
    pending: AtomicUsize,
    /// Live senders; 0 = disconnected.
    producers: AtomicUsize,
    /// False once the receiver is gone; sends then fail.
    open: AtomicBool,
    /// Round-robin shard assignment for cloned senders.
    next_shard: AtomicUsize,
    gate: Mutex<()>,
    cv: Condvar,
}

/// Producer handle, pinned to one shard; clone to mint more (each clone
/// is pinned round-robin to the next shard).
pub struct BusSender<T> {
    inner: Arc<Inner<T>>,
    shard: usize,
}

/// Single consumer; owns the spare buffers used for wholesale sweeps.
pub struct BusReceiver<T> {
    inner: Arc<Inner<T>>,
    spares: Vec<Vec<T>>,
    /// Rotates the first shard swept so no shard is starved by budgeted
    /// drains.
    cursor: usize,
}

/// Outcome of a blocking receive.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvStatus {
    /// `n > 0` items were appended to the caller's buffer.
    Items(usize),
    /// Deadline passed with nothing available.
    TimedOut,
    /// Every sender is gone and the bus is drained.
    Disconnected,
}

/// Create a bus with `shards` producer slots (clamped to at least 1).
pub fn channel<T>(shards: usize) -> (BusSender<T>, BusReceiver<T>) {
    let n = shards.max(1);
    let inner = Arc::new(Inner {
        shards: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        pending: AtomicUsize::new(0),
        producers: AtomicUsize::new(1),
        open: AtomicBool::new(true),
        next_shard: AtomicUsize::new(1),
        gate: Mutex::new(()),
        cv: Condvar::new(),
    });
    let tx = BusSender { inner: inner.clone(), shard: 0 };
    let rx = BusReceiver {
        inner,
        spares: (0..n).map(|_| Vec::new()).collect(),
        cursor: 0,
    };
    (tx, rx)
}

impl<T> Clone for BusSender<T> {
    fn clone(&self) -> Self {
        self.inner.producers.fetch_add(1, Ordering::AcqRel);
        let shard =
            self.inner.next_shard.fetch_add(1, Ordering::Relaxed) % self.inner.shards.len();
        BusSender { inner: self.inner.clone(), shard }
    }
}

impl<T> Drop for BusSender<T> {
    fn drop(&mut self) {
        if self.inner.producers.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last sender gone: wake the receiver so drain loops can
            // observe the disconnect instead of sleeping out their
            // timeout.
            let _g = self.inner.gate.plock();
            self.inner.cv.notify_all();
        }
    }
}

impl<T> BusSender<T> {
    /// Push one item. Fails (returning the item) once the receiver has
    /// been dropped — the worker-loop exit signal.
    pub fn send(&self, item: T) -> Result<(), T> {
        if !self.inner.open.load(Ordering::Acquire) {
            return Err(item);
        }
        {
            let mut q = self.inner.shards[self.shard].plock();
            q.push(item);
            // Counted under the shard lock, so the receiver's matching
            // fetch_sub (also under this lock) can never underflow.
            self.inner.pending.fetch_add(1, Ordering::Release);
        }
        // Taking the gate orders this wakeup after the receiver's
        // pending re-check, so the notify cannot be lost.
        drop(self.inner.gate.plock());
        self.inner.cv.notify_one();
        Ok(())
    }
}

impl<T> Drop for BusReceiver<T> {
    fn drop(&mut self) {
        self.inner.open.store(false, Ordering::Release);
    }
}

impl<T> BusReceiver<T> {
    /// Sweep up to `budget` items into `buf` without blocking; returns
    /// how many were appended. Whole shards are swapped out against
    /// reusable spares, so an unbudgeted sweep of a burst costs one lock
    /// round per shard and no allocation.
    pub fn try_drain(&mut self, buf: &mut Vec<T>, budget: usize) -> usize {
        let n_shards = self.inner.shards.len();
        let mut got = 0usize;
        for step in 0..n_shards {
            if got >= budget {
                break;
            }
            let i = (self.cursor + step) % n_shards;
            let mut q = self.inner.shards[i].plock();
            let avail = q.len();
            if avail == 0 {
                continue;
            }
            let take = avail.min(budget - got);
            if take == avail {
                let spare = &mut self.spares[i];
                std::mem::swap(&mut *q, spare);
                self.inner.pending.fetch_sub(take, Ordering::Release);
                drop(q);
                buf.append(spare);
            } else {
                buf.extend(q.drain(..take));
                self.inner.pending.fetch_sub(take, Ordering::Release);
            }
            got += take;
        }
        self.cursor = (self.cursor + 1) % n_shards;
        got
    }

    /// Blocking receive: appends up to `budget` items to `buf`, waiting
    /// until `deadline` for the first to arrive. Never waits past
    /// `deadline` (the caller's wait budget is the hard bound — see the
    /// `poll_timeout` double-wait fix).
    pub fn recv_deadline(
        &mut self,
        deadline: Instant,
        buf: &mut Vec<T>,
        budget: usize,
    ) -> RecvStatus {
        loop {
            let got = self.try_drain(buf, budget);
            if got > 0 {
                return RecvStatus::Items(got);
            }
            if self.inner.producers.load(Ordering::Acquire) == 0
                && self.inner.pending.load(Ordering::Acquire) == 0
            {
                return RecvStatus::Disconnected;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvStatus::TimedOut;
            }
            let gate = self.inner.gate.plock();
            // Re-check under the gate: a sender that bumped `pending`
            // before we parked also takes the gate, so either we see the
            // item now or its notify lands after we wait.
            if self.inner.pending.load(Ordering::Acquire) > 0 {
                continue;
            }
            if self.inner.producers.load(Ordering::Acquire) == 0 {
                return RecvStatus::Disconnected;
            }
            let (_g, _res) = self.inner.cv.pwait_timeout(gate, deadline - now);
        }
    }

    /// Items pushed and not yet drained (advisory).
    pub fn pending(&self) -> usize {
        self.inner.pending.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fans_in_from_many_senders() {
        let (tx, mut rx) = channel::<u32>(4);
        let mut handles = Vec::new();
        for p in 0..8u32 {
            let txc = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u32 {
                    txc.send(p * 100 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut got = Vec::new();
        while got.len() < 800 {
            match rx.recv_deadline(
                Instant::now() + Duration::from_secs(2),
                &mut got,
                usize::MAX,
            ) {
                RecvStatus::Items(_) => {}
                RecvStatus::TimedOut => panic!("timed out with {} items", got.len()),
                RecvStatus::Disconnected => break,
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        let want: Vec<u32> = (0..8).flat_map(|p| (0..100).map(move |i| p * 100 + i)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn disconnects_when_last_sender_drops() {
        let (tx, mut rx) = channel::<u32>(2);
        tx.send(1).unwrap();
        drop(tx);
        let mut buf = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(1);
        assert_eq!(rx.recv_deadline(deadline, &mut buf, usize::MAX), RecvStatus::Items(1));
        assert_eq!(
            rx.recv_deadline(deadline, &mut buf, usize::MAX),
            RecvStatus::Disconnected
        );
    }

    #[test]
    fn send_fails_after_receiver_drops() {
        let (tx, rx) = channel::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(9), Err(9));
    }

    #[test]
    fn recv_deadline_respects_the_deadline() {
        let (_tx, mut rx) = channel::<u32>(2);
        let mut buf = Vec::new();
        let t0 = Instant::now();
        let status =
            rx.recv_deadline(t0 + Duration::from_millis(30), &mut buf, usize::MAX);
        assert_eq!(status, RecvStatus::TimedOut);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(29), "waited {waited:?}");
        assert!(waited < Duration::from_millis(300), "overshot: {waited:?}");
    }

    #[test]
    fn budget_bounds_one_sweep_and_the_rest_survives() {
        let (tx, mut rx) = channel::<u32>(3);
        for i in 0..50 {
            tx.send(i).unwrap();
        }
        let mut buf = Vec::new();
        let got = rx.try_drain(&mut buf, 16);
        assert!(got <= 16, "budget exceeded: {got}");
        while rx.try_drain(&mut buf, 16) > 0 {}
        buf.sort_unstable();
        assert_eq!(buf, (0..50).collect::<Vec<_>>());
        assert_eq!(rx.pending(), 0);
    }

    #[test]
    fn wakes_blocked_receiver_on_send() {
        let (tx, mut rx) = channel::<u32>(2);
        let h = std::thread::spawn(move || {
            let mut buf = Vec::new();
            let status = rx.recv_deadline(
                Instant::now() + Duration::from_secs(2),
                &mut buf,
                usize::MAX,
            );
            (status, buf)
        });
        std::thread::sleep(Duration::from_millis(20));
        tx.send(42).unwrap();
        let (status, buf) = h.join().unwrap();
        assert_eq!(status, RecvStatus::Items(1));
        assert_eq!(buf, vec![42]);
    }
}

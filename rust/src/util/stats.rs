//! Latency statistics substrate: exact percentile summaries and the
//! fixed-duration sampling harness used by `rust/benches/*` (criterion is
//! not in the build image).

use std::time::{Duration, Instant};

/// Collects raw samples; computes exact order-statistics on demand.
/// The paper reports median and 99.9th percentile over 100k queries —
/// at that scale exact sorting is cheap and avoids sketch error.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { samples: Vec::with_capacity(n), sorted: false }
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64() * 1e3); // milliseconds
    }

    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_unstable_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
            self.sorted = true;
        }
    }

    /// Exact percentile via nearest-rank (p in [0, 100]).
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        self.samples[rank.clamp(1, n) - 1]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn p999(&mut self) -> f64 {
        self.percentile(99.9)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples[0]
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        *self.samples.last().unwrap()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std_dev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64)
            .sqrt()
    }

    /// One-line report: `n=… mean=… p50=… p99=… p99.9=… max=…` (ms units by
    /// convention when filled via `record_duration`).
    pub fn report(&mut self, label: &str) -> String {
        if self.is_empty() {
            return format!("{label}: (no samples)");
        }
        format!(
            "{label}: n={} mean={:.3} p50={:.3} p99={:.3} p99.9={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.median(),
            self.p99(),
            self.p999(),
            self.max()
        )
    }
}

/// Micro-benchmark harness: warm up, then sample `f` for at least
/// `min_duration` and `min_iters`, reporting per-iteration latency stats.
pub fn bench<F: FnMut()>(
    label: &str,
    warmup: usize,
    min_iters: usize,
    min_duration: Duration,
    mut f: F,
) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::with_capacity(min_iters);
    let start = Instant::now();
    let mut iters = 0usize;
    while iters < min_iters || start.elapsed() < min_duration {
        let t = Instant::now();
        f();
        s.record(t.elapsed().as_secs_f64() * 1e3);
        iters += 1;
        if iters >= 10_000_000 {
            break;
        }
    }
    log::debug!("{}", s.clone().report(label));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.median(), 50.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(1.0), 1.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn p999_picks_tail() {
        let mut s = Summary::new();
        for _ in 0..999 {
            s.record(1.0);
        }
        s.record(100.0);
        assert_eq!(s.p999(), 100.0);
        assert_eq!(s.median(), 1.0);
    }

    #[test]
    fn single_sample() {
        let mut s = Summary::new();
        s.record(3.5);
        assert_eq!(s.median(), 3.5);
        assert_eq!(s.p999(), 3.5);
        assert_eq!(s.mean(), 3.5);
    }

    #[test]
    fn merge_combines() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.max(), 3.0);
    }

    #[test]
    fn mean_std() {
        let mut s = Summary::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(v);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std_dev() - 2.138_089_935).abs() < 1e-6);
    }

    #[test]
    fn bench_runs() {
        let s = bench("noop", 2, 10, Duration::from_millis(1), || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.len() >= 10);
    }
}

//! Tiny argument-parsing substrate (clap is not in the build image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each experiment binary declares its options up front so `--help` output
//! is uniform across the CLI, benches, and examples.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    BadValue(String, String),
    #[error("help requested")]
    Help,
}

pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self { program, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, default: Some(default), help, is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, default: None, help, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, default: None, help, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let d = match (spec.is_flag, spec.default) {
                (true, _) => String::new(),
                (false, Some(d)) => format!(" [default: {d}]"),
                (false, None) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s.push_str("  --help               show this message\n");
        s
    }

    /// Parse an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(CliError::Help);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.is_flag {
                    args.flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    args.values.insert(name, v);
                }
            } else {
                args.positional.push(a);
            }
        }
        // Fill defaults.
        for spec in &self.specs {
            if !spec.is_flag && !args.values.contains_key(spec.name) {
                if let Some(d) = spec.default {
                    args.values.insert(spec.name.to_string(), d.to_string());
                } else {
                    return Err(CliError::MissingValue(spec.name.to_string()));
                }
            }
        }
        Ok(args)
    }

    /// Parse std::env::args(); print usage and exit on --help or error.
    pub fn parse_env(&self) -> Args {
        match self.parse(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(CliError::Help) => {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: {e}"))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Millisecond-valued option as a `Duration` (fractional ok, e.g.
    /// `--admission-timeout-ms 2.5`).
    pub fn get_duration_ms(&self, name: &str) -> std::time::Duration {
        let ms = self.get_f64(name);
        if ms.is_nan() || ms < 0.0 {
            panic!("--{name}: must be >= 0 ms, got {ms}");
        }
        std::time::Duration::from_secs_f64(ms / 1e3)
    }

    /// Comma-separated list, e.g. `--ks 2,3,4`.
    pub fn get_list_usize(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|e| panic!("--{name}: {e}")))
            .collect()
    }

    pub fn get_list_f64(&self, name: &str) -> Vec<f64> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|e| panic!("--{name}: {e}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rate", "100", "query rate")
            .opt("ks", "2,3,4", "k values")
            .req("model", "model name")
            .flag("verbose", "more output")
    }

    fn parse(args: &[&str]) -> Result<Args, CliError> {
        cli().parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&["--model", "m1"]).unwrap();
        assert_eq!(a.get("rate"), "100");
        let a = parse(&["--model", "m1", "--rate=250"]).unwrap();
        assert_eq!(a.get_usize("rate"), 250);
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["--model", "m", "--verbose", "pos1"]).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(!parse(&["--model", "m"]).unwrap().has_flag("verbose"));
    }

    #[test]
    fn lists() {
        let a = parse(&["--model", "m", "--ks", "2, 3,4"]).unwrap();
        assert_eq!(a.get_list_usize("ks"), vec![2, 3, 4]);
    }

    #[test]
    fn durations() {
        let cli = Cli::new("t", "test").opt("timeout-ms", "50", "timeout");
        let a = cli.parse(Vec::new()).unwrap();
        assert_eq!(a.get_duration_ms("timeout-ms"), std::time::Duration::from_millis(50));
        let a = cli.parse(vec!["--timeout-ms=2.5".to_string()]).unwrap();
        assert_eq!(a.get_duration_ms("timeout-ms"), std::time::Duration::from_micros(2500));
    }

    #[test]
    fn errors() {
        assert!(matches!(parse(&[]), Err(CliError::MissingValue(_))));
        assert!(matches!(parse(&["--bogus", "1"]), Err(CliError::Unknown(_))));
        assert!(matches!(parse(&["--model"]), Err(CliError::MissingValue(_))));
        assert!(matches!(parse(&["--help"]), Err(CliError::Help)));
    }
}

//! Simulated multi-tenant cluster substrate.
//!
//! The paper evaluates on EC2 GPU/CPU clusters where tail latency comes
//! from *load imbalance*: background network shuffles and multi-tenant
//! inference slow a random subset of model instances (§5.1). No cluster
//! exists in this image, so we reproduce the same mechanisms in-process:
//!
//! - every model instance is an OS thread running real PJRT inference;
//! - a [`hardware::Profile`] scales its effective service time (GPU-class
//!   vs CPU-class instances, and the §5.2.6 approximate model's
//!   hardware-dependent speedup);
//! - [`network::Network`] models per-instance links with background
//!   shuffles that inflate transfer times while in flight;
//! - [`tenancy::Tenancy`] adds light co-located inference load on a subset
//!   of instances (§5.2.4);
//! - [`faults::FaultPlan`] injects hard failures (instances that stop
//!   responding), the limiting case of a slowdown;
//! - [`chaos::FaultScript`] scripts all of the above deterministically:
//!   seeded, step-indexed fault timelines against any serving tier.
//!
//! All injected delays scale by `time_scale` so experiments can run
//! compressed (e.g. 0.2x) while preserving the ratios that determine
//! queueing behaviour; EXPERIMENTS.md records the scale used per figure.

pub mod chaos;
pub mod faults;
pub mod hardware;
pub mod network;
pub mod tenancy;

use std::time::Duration;

/// Scale a duration by the experiment's time-compression factor.
pub fn scaled(d: Duration, time_scale: f64) -> Duration {
    Duration::from_secs_f64(d.as_secs_f64() * time_scale)
}

/// Sleep for an injected delay. Spinning is only used for genuinely tiny
/// waits (< 50 us): the build host may have very few cores (the CI image
/// has one), where busy-waiting in tens of worker threads would starve
/// the PJRT execution pool and corrupt every measurement.
pub fn precise_sleep(d: Duration) {
    if d.is_zero() {
        return;
    }
    if d > Duration::from_micros(50) {
        std::thread::sleep(d);
    } else {
        let start = std::time::Instant::now();
        while start.elapsed() < d {
            std::hint::spin_loop();
        }
    }
}

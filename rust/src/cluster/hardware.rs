//! Hardware profiles for simulated model instances.
//!
//! The paper's two clusters (§5.1): 12x p2.xlarge (K80 GPU, 1-2 Gbps to
//! the frontend) and 24x c5.xlarge (CPU, 4-5 Gbps). A profile scales the
//! *measured* PJRT execution time of this machine up to the target
//! service time by sleeping the residual, so the distribution keeps the
//! real execution's natural jitter while matching the cluster's scale.

use std::time::Duration;

#[derive(Clone, Debug, PartialEq)]
pub struct Profile {
    pub name: &'static str,
    /// Multiplier on measured execution time (>= 1.0 adds sleep; < 1.0 is
    /// clamped — we cannot make real inference faster).
    pub exec_scale: f64,
    /// Frontend<->instance link bandwidth, bytes/sec.
    pub link_bandwidth: f64,
    /// Fixed per-dispatch overhead (RPC, serialization).
    pub dispatch_overhead: Duration,
    /// Number of deployed-model instances `m` in the paper's cluster.
    pub default_m: usize,
}

/// GPU cluster: 12 instances, 1.5 Gbps links (midpoint of the observed
/// 1-2 Gbps), batched-friendly hardware.
pub const GPU: Profile = Profile {
    name: "gpu",
    exec_scale: 1.0,
    link_bandwidth: 1.5e9 / 8.0,
    dispatch_overhead: Duration::from_micros(150),
    default_m: 12,
};

/// CPU cluster: 24 instances, 4.5 Gbps links, ~2x slower per-query
/// inference than the GPU profile (the paper's c5.xlarge vs K80 ratio for
/// ResNet-18 at batch 1 is close to parity; we keep a mild 1.5x).
pub const CPU: Profile = Profile {
    name: "cpu",
    exec_scale: 1.5,
    link_bandwidth: 4.5e9 / 8.0,
    dispatch_overhead: Duration::from_micros(100),
    default_m: 24,
};

pub fn by_name(name: &str) -> Option<&'static Profile> {
    match name {
        "gpu" => Some(&GPU),
        "cpu" => Some(&CPU),
        _ => None,
    }
}

impl Profile {
    /// Residual sleep to apply after a real execution of `measured`.
    pub fn residual(&self, measured: Duration) -> Duration {
        if self.exec_scale <= 1.0 {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(measured.as_secs_f64() * (self.exec_scale - 1.0))
    }

    /// Uncontended transfer time for a payload of `bytes`.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.link_bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_scales() {
        let p = Profile { exec_scale: 3.0, ..GPU };
        let r = p.residual(Duration::from_millis(2));
        assert_eq!(r, Duration::from_millis(4));
        assert_eq!(GPU.residual(Duration::from_millis(2)), Duration::ZERO);
    }

    #[test]
    fn transfer_time_linear() {
        let t1 = GPU.transfer_time(1_000_000);
        let t2 = GPU.transfer_time(2_000_000);
        assert!((t2.as_secs_f64() / t1.as_secs_f64() - 2.0).abs() < 1e-6);
        // 1 MB over 1.5 Gbps ≈ 5.3 ms.
        assert!((t1.as_secs_f64() - 0.00533).abs() < 0.0005);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("gpu").unwrap().default_m, 12);
        assert_eq!(by_name("cpu").unwrap().default_m, 24);
        assert!(by_name("tpu").is_none());
    }
}

//! Light multi-tenant inference load (§5.2.4, Figure 14).
//!
//! The paper deploys a second copy of the serving system on one ninth of
//! the instances and sends it < 5% of cluster capacity — a light,
//! compute-level form of imbalance (no network component). We model the
//! co-located tenant as a Poisson stream of background jobs per tenant
//! instance; while a background job runs, the instance's effective
//! service rate halves (two processes share the accelerator/cores).

use crate::util::rng::Pcg64;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct Tenancy {
    /// Which instances host a tenant.
    pub tenant_instances: Vec<usize>,
    /// Per-tenant-instance background arrival rate (jobs/sec, already
    /// time-scaled).
    pub bg_rate: f64,
    /// Background job service time.
    pub bg_service: Duration,
    /// Service-time multiplier applied to foreground queries while a
    /// background job overlaps.
    pub slowdown: f64,
}

impl Tenancy {
    /// No multitenancy.
    pub fn none() -> Tenancy {
        Tenancy {
            tenant_instances: Vec::new(),
            bg_rate: 0.0,
            bg_service: Duration::ZERO,
            slowdown: 1.0,
        }
    }

    /// The paper's configuration: tenants on 1/9th of instances, load
    /// under 5% of what the tenant instances could sustain.
    pub fn light(m: usize, mean_service: Duration, rng: &mut Pcg64) -> Tenancy {
        let n_tenants = (m as f64 / 9.0).ceil() as usize;
        let tenant_instances = rng.choose_distinct(m, n_tenants);
        let per_instance_capacity = 1.0 / mean_service.as_secs_f64().max(1e-6);
        Tenancy {
            tenant_instances,
            bg_rate: 0.05 * per_instance_capacity,
            bg_service: mean_service,
            slowdown: 2.0,
        }
    }

    pub fn is_tenant(&self, instance: usize) -> bool {
        self.tenant_instances.contains(&instance)
    }

    pub fn enabled(&self) -> bool {
        !self.tenant_instances.is_empty() && self.bg_rate > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn light_config_matches_paper_shape() {
        let mut rng = Pcg64::new(5);
        let t = Tenancy::light(18, Duration::from_millis(10), &mut rng);
        assert_eq!(t.tenant_instances.len(), 2); // ceil(18/9)
        // <5% of a 100 qps instance => 5 jobs/sec.
        assert!((t.bg_rate - 5.0).abs() < 1e-9);
        assert!(t.enabled());
        let inst = t.tenant_instances[0];
        assert!(t.is_tenant(inst));
    }

    #[test]
    fn none_is_disabled() {
        let t = Tenancy::none();
        assert!(!t.enabled());
        assert!(!t.is_tenant(0));
    }
}

//! Hard-failure injection: the limiting case of a slowdown.
//!
//! ParM is agnostic to the cause of unavailability (§1); a crashed or
//! hung instance is simply one that never returns. The fault plan marks
//! instances as failed during configured windows; the instance worker
//! drops (never answers) jobs received while failed. Used by the
//! failure-injection integration tests and the `quickstart` example.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::journal::{Event, FaultKind, Recorder};

/// Lock-free fault schedule: per-instance "failed until" timestamps,
/// stored as nanos since the plan's epoch.
pub struct FaultPlan {
    epoch: std::time::Instant,
    failed_until: Vec<AtomicU64>,
    /// Journal hook: every mutation is recorded here, so the event log
    /// captures faults from *any* source — scripted harness, scheduled
    /// injector, or a manual chaos-drill kill. Disabled by default.
    recorder: Recorder,
}

impl FaultPlan {
    pub fn new(n_instances: usize) -> Arc<FaultPlan> {
        Self::new_recorded(n_instances, Recorder::disabled())
    }

    /// A plan whose mutations land in a serving-path journal.
    pub fn new_recorded(n_instances: usize, recorder: Recorder) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            epoch: std::time::Instant::now(),
            failed_until: (0..n_instances).map(|_| AtomicU64::new(0)).collect(),
            recorder,
        })
    }

    /// Mark `instance` failed for `dur` starting now.
    pub fn fail_for(&self, instance: usize, dur: Duration) {
        let until = (self.epoch.elapsed() + dur).as_nanos() as u64;
        self.failed_until[instance].store(until, Ordering::Relaxed);
        self.recorder.record(&Event::Fault {
            instance: instance as u64,
            kind: FaultKind::FailFor as u8,
            arg: dur.as_micros() as u64,
        });
    }

    /// Permanently fail an instance.
    pub fn kill(&self, instance: usize) {
        self.failed_until[instance].store(u64::MAX, Ordering::Relaxed);
        self.recorder.record(&Event::Fault {
            instance: instance as u64,
            kind: FaultKind::Kill as u8,
            arg: 0,
        });
    }

    /// Clear any failure on an instance.
    pub fn heal(&self, instance: usize) {
        self.failed_until[instance].store(0, Ordering::Relaxed);
        self.recorder.record(&Event::Fault {
            instance: instance as u64,
            kind: FaultKind::Heal as u8,
            arg: 0,
        });
    }

    pub fn is_failed(&self, instance: usize) -> bool {
        let until = self.failed_until[instance].load(Ordering::Relaxed);
        until == u64::MAX || (self.epoch.elapsed().as_nanos() as u64) < until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fail_heal_cycle() {
        let plan = FaultPlan::new(3);
        assert!(!plan.is_failed(1));
        plan.fail_for(1, Duration::from_millis(30));
        assert!(plan.is_failed(1));
        assert!(!plan.is_failed(0));
        std::thread::sleep(Duration::from_millis(40));
        assert!(!plan.is_failed(1), "failure window expired");
        plan.kill(2);
        assert!(plan.is_failed(2));
        plan.heal(2);
        assert!(!plan.is_failed(2));
    }
}

//! Deterministic fault-injection harness: seeded, step-indexed chaos
//! scripts against any serving tier.
//!
//! Chaos used to be ad hoc per test: a sleep, then a hand-rolled
//! `kill_instance` at whatever instant the scheduler reached. This
//! harness makes fault timelines *data*: a seeded [`FaultScript`] of
//! (step, action) events, where a step is the index of a submitted
//! query — not wall time — so the same seed produces the same fault
//! pattern relative to the traffic on every run and host. Drive it with
//! one line in a submit loop:
//!
//! ```ignore
//! let surface = FaultSurface::sharded(plans, m).with_networks(nets);
//! let mut script = FaultScript::builder(seed)
//!     .kill_shard_at(40, 1)
//!     .degrade_link_at(60, 0, 1, 32)
//!     .build();
//! for i in 0..n {
//!     script.apply(i, &surface);
//!     client.submit(...);
//! }
//! ```
//!
//! Actions cover the repo's failure models: single-instance zombies
//! ([`FaultAction::KillInstance`]), whole-fault-domain loss
//! ([`FaultAction::KillShard`]), bounded brown-outs
//! ([`FaultAction::Straggle`]), correlated multi-shard bursts
//! ([`FaultAction::CorrelatedKill`] — the case cross-shard coding sizes
//! its r for), and link degradation
//! ([`FaultAction::DegradeLink`]/[`FaultAction::RestoreLink`], phantom
//! background flows pinned on one instance's link via
//! [`Network::degrade_link`]).
//!
//! Instance-failure actions land on [`FaultPlan`]s, which journal them
//! ([`crate::coordinator::journal::Event::Fault`]) when the run carries
//! a live recorder. Link actions go through [`Network`], which has no
//! journal hook of its own — attach one to the surface with
//! [`FaultSurface::with_recorder`] and they are journaled too.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::faults::FaultPlan;
use crate::cluster::network::Network;
use crate::coordinator::journal::{Event, FaultKind, Recorder};
use crate::util::rng::Pcg64;

/// One scripted fault.
#[derive(Clone, Debug)]
pub enum FaultAction {
    /// Permanently kill one instance of one shard (undetected zombie).
    KillInstance { shard: usize, instance: usize },
    /// Permanently kill every instance of one shard (whole fault
    /// domain).
    KillShard { shard: usize },
    /// Fail one instance for a bounded window (brown-out).
    Straggle { shard: usize, instance: usize, dur: Duration },
    /// Correlated burst: kill every instance of several shards at once.
    CorrelatedKill { shards: Vec<usize> },
    /// Pin `flows` phantom background flows on one instance's link
    /// (replacing any previous degradation there) — transfers and
    /// head-of-line delays inflate as if that many shuffles were stuck
    /// on it.
    DegradeLink { shard: usize, instance: usize, flows: u32 },
    /// Clear chaos-injected degradation on one instance's link.
    RestoreLink { shard: usize, instance: usize },
}

/// Where scripted faults land: the per-shard fault plans of whatever is
/// under test (a bare session, a `ShardedFrontend`, a
/// `CrossShardFrontend` — all expose `fault_plan(...)`), plus the
/// instance count a whole-shard kill must cover, plus (optionally) the
/// per-shard link-contention models for the network actions.
pub struct FaultSurface {
    instances_per_shard: usize,
    plans: Vec<Arc<FaultPlan>>,
    /// Per-shard link models; empty unless
    /// [`FaultSurface::with_networks`] supplied them. Network actions
    /// against a shard with no model are ignored (a retired shard has no
    /// links left to degrade).
    networks: Vec<Option<Arc<Network>>>,
    /// Journals link actions (fault-plan actions journal themselves).
    recorder: Recorder,
}

impl FaultSurface {
    /// A single-session target (shard index is always 0).
    pub fn single(plan: Arc<FaultPlan>, instances: usize) -> FaultSurface {
        FaultSurface {
            instances_per_shard: instances,
            plans: vec![plan],
            networks: Vec::new(),
            recorder: Recorder::disabled(),
        }
    }

    /// A sharded target: one fault plan per shard, `instances_per_shard`
    /// deployed instances each (ids 0..m within each shard's plan).
    pub fn sharded(plans: Vec<Arc<FaultPlan>>, instances_per_shard: usize) -> FaultSurface {
        assert!(!plans.is_empty());
        FaultSurface {
            instances_per_shard,
            plans,
            networks: Vec::new(),
            recorder: Recorder::disabled(),
        }
    }

    /// Supply per-shard link models so [`FaultAction::DegradeLink`] /
    /// [`FaultAction::RestoreLink`] have somewhere to land (`None` for
    /// shards whose network is unavailable, e.g. retired ones).
    pub fn with_networks(mut self, networks: Vec<Option<Arc<Network>>>) -> FaultSurface {
        self.networks = networks;
        self
    }

    /// Journal link actions through `recorder` (tagged per shard).
    /// Fault-plan actions need nothing here — a recorded plan journals
    /// its own mutations.
    pub fn with_recorder(mut self, recorder: Recorder) -> FaultSurface {
        self.recorder = recorder;
        self
    }

    pub fn shards(&self) -> usize {
        self.plans.len()
    }

    pub fn instances_per_shard(&self) -> usize {
        self.instances_per_shard
    }

    pub fn kill(&self, shard: usize, instance: usize) {
        self.plans[shard].kill(instance);
    }

    pub fn fail_for(&self, shard: usize, instance: usize, dur: Duration) {
        self.plans[shard].fail_for(instance, dur);
    }

    /// Degrade one instance's link with `flows` phantom flows (no-op if
    /// the shard has no link model attached).
    pub fn degrade_link(&self, shard: usize, instance: usize, flows: u32) {
        if let Some(Some(net)) = self.networks.get(shard) {
            net.degrade_link(instance, flows);
            self.recorder.tagged(shard as u64).record(&Event::Fault {
                instance: instance as u64,
                kind: FaultKind::Degrade as u8,
                arg: u64::from(flows),
            });
        }
    }

    /// Clear chaos degradation on one instance's link (no-op without a
    /// link model).
    pub fn restore_link(&self, shard: usize, instance: usize) {
        if let Some(Some(net)) = self.networks.get(shard) {
            net.restore_link(instance);
            self.recorder.tagged(shard as u64).record(&Event::Fault {
                instance: instance as u64,
                kind: FaultKind::Restore as u8,
                arg: 0,
            });
        }
    }

    fn kill_shard(&self, shard: usize) {
        for i in 0..self.instances_per_shard {
            self.plans[shard].kill(i);
        }
    }
}

/// A seeded, step-indexed fault timeline. Build with
/// [`FaultScript::builder`]; call [`FaultScript::apply`] once per
/// submitted query with the query's index.
pub struct FaultScript {
    /// (step, action), sorted by step.
    events: Vec<(u64, FaultAction)>,
    next: usize,
}

impl FaultScript {
    pub fn builder(seed: u64) -> FaultScriptBuilder {
        FaultScriptBuilder { rng: Pcg64::new(seed), events: Vec::new() }
    }

    /// Fire every action due at or before `step`.
    pub fn apply(&mut self, step: u64, surface: &FaultSurface) {
        while self.next < self.events.len() && self.events[self.next].0 <= step {
            match &self.events[self.next].1 {
                FaultAction::KillInstance { shard, instance } => {
                    surface.kill(*shard, *instance);
                }
                FaultAction::KillShard { shard } => surface.kill_shard(*shard),
                FaultAction::Straggle { shard, instance, dur } => {
                    surface.fail_for(*shard, *instance, *dur);
                }
                FaultAction::CorrelatedKill { shards } => {
                    for &s in shards {
                        surface.kill_shard(s);
                    }
                }
                FaultAction::DegradeLink { shard, instance, flows } => {
                    surface.degrade_link(*shard, *instance, *flows);
                }
                FaultAction::RestoreLink { shard, instance } => {
                    surface.restore_link(*shard, *instance);
                }
            }
            self.next += 1;
        }
    }

    /// Whether every scripted action has fired.
    pub fn done(&self) -> bool {
        self.next >= self.events.len()
    }

    /// The scripted actions (inspection/logging).
    pub fn events(&self) -> &[(u64, FaultAction)] {
        &self.events
    }
}

/// Builder for [`FaultScript`]: explicit placements plus seeded random
/// choices (which shard dies, which shards fail together) so soak
/// suites get diverse-but-reproducible trials from one seed.
pub struct FaultScriptBuilder {
    rng: Pcg64,
    events: Vec<(u64, FaultAction)>,
}

impl FaultScriptBuilder {
    pub fn kill_instance_at(mut self, step: u64, shard: usize, instance: usize) -> Self {
        self.events.push((step, FaultAction::KillInstance { shard, instance }));
        self
    }

    pub fn kill_shard_at(mut self, step: u64, shard: usize) -> Self {
        self.events.push((step, FaultAction::KillShard { shard }));
        self
    }

    pub fn straggle_at(
        mut self,
        step: u64,
        shard: usize,
        instance: usize,
        dur: Duration,
    ) -> Self {
        self.events.push((step, FaultAction::Straggle { shard, instance, dur }));
        self
    }

    pub fn correlated_kill_at(mut self, step: u64, shards: Vec<usize>) -> Self {
        self.events.push((step, FaultAction::CorrelatedKill { shards }));
        self
    }

    /// Pin `flows` phantom flows on one instance's link at `step`.
    pub fn degrade_link_at(
        mut self,
        step: u64,
        shard: usize,
        instance: usize,
        flows: u32,
    ) -> Self {
        self.events.push((step, FaultAction::DegradeLink { shard, instance, flows }));
        self
    }

    /// Clear that degradation at `step`.
    pub fn restore_link_at(mut self, step: u64, shard: usize, instance: usize) -> Self {
        self.events.push((step, FaultAction::RestoreLink { shard, instance }));
        self
    }

    /// Kill one seeded-random shard out of `shards` at `step`.
    pub fn random_shard_kill_at(mut self, step: u64, shards: usize) -> Self {
        let s = self.rng.below(shards as u64) as usize;
        self.events.push((step, FaultAction::KillShard { shard: s }));
        self
    }

    /// Kill `count` seeded-random distinct shards together at `step`
    /// (the correlated burst).
    pub fn random_correlated_kill_at(mut self, step: u64, shards: usize, count: usize) -> Self {
        let picked = self.rng.choose_distinct(shards, count.min(shards));
        self.events.push((step, FaultAction::CorrelatedKill { shards: picked }));
        self
    }

    /// A seeded step in `[lo, hi]` (for randomizing *when* a scripted
    /// fault lands).
    pub fn random_step(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn build(mut self) -> FaultScript {
        self.events.sort_by_key(|&(step, _)| step);
        FaultScript { events: self.events, next: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hardware::GPU;

    #[test]
    fn script_fires_in_step_order_and_reports_done() {
        let plans = vec![FaultPlan::new(4), FaultPlan::new(4)];
        let surface = FaultSurface::sharded(plans.clone(), 4);
        let mut script = FaultScript::builder(7)
            .kill_instance_at(5, 1, 2)
            .kill_shard_at(2, 0)
            .build();
        assert!(!script.done());
        script.apply(1, &surface);
        assert!(!plans[0].is_failed(0), "step 2 not reached yet");
        script.apply(3, &surface);
        assert!((0..4).all(|i| plans[0].is_failed(i)), "shard 0 killed at step 2");
        assert!(!plans[1].is_failed(2));
        script.apply(10, &surface);
        assert!(plans[1].is_failed(2));
        assert!(script.done());
    }

    #[test]
    fn same_seed_same_random_script() {
        let build = |seed| {
            FaultScript::builder(seed)
                .random_shard_kill_at(10, 8)
                .random_correlated_kill_at(20, 8, 3)
                .build()
        };
        let a = build(99);
        let b = build(99);
        assert_eq!(format!("{:?}", a.events()), format!("{:?}", b.events()));
        let c = build(100);
        // Different seeds *may* coincide; these don't (pinned).
        assert_ne!(format!("{:?}", a.events()), format!("{:?}", c.events()));
    }

    #[test]
    fn link_actions_hit_the_network_and_skip_absent_shards() {
        let plans = vec![FaultPlan::new(2), FaultPlan::new(2)];
        let net = Network::new(2, &GPU);
        let surface = FaultSurface::sharded(plans, 2)
            .with_networks(vec![Some(net.clone()), None]);
        let mut script = FaultScript::builder(1)
            .degrade_link_at(0, 0, 1, 16)
            .degrade_link_at(0, 1, 0, 16) // shard 1 has no link model
            .restore_link_at(5, 0, 1)
            .build();
        script.apply(0, &surface);
        assert_eq!(net.degraded_flows(1), 16);
        script.apply(5, &surface);
        assert_eq!(net.degraded_flows(1), 0);
        assert!(script.done());
    }
}

//! Link-contention model with background shuffles (§5.1 "Background
//! traffic").
//!
//! The paper's main source of load imbalance: pairs of randomly chosen
//! instances transfer 128-256 MB to each other; while such a shuffle is in
//! flight, the two instances' frontend links are contended and query /
//! prediction transfers on them slow down. A scheduler thread keeps a
//! target number of shuffles alive at all times (the paper uses 4 by
//! default, 2/3/5 in Figure 13).

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::rng::Pcg64;

use super::hardware::Profile;

/// Shared per-instance contention counters.
pub struct Network {
    /// Number of active background flows on each instance's link.
    contention: Vec<AtomicU32>,
    /// Chaos-injected phantom flows per link (subset of `contention`),
    /// tracked separately so [`Network::restore_link`] removes exactly
    /// what [`Network::degrade_link`] added and never touches live
    /// shuffle flows.
    degraded: Vec<AtomicU32>,
    profile: &'static Profile,
}

impl Network {
    pub fn new(n_instances: usize, profile: &'static Profile) -> Arc<Self> {
        Arc::new(Self {
            contention: (0..n_instances).map(|_| AtomicU32::new(0)).collect(),
            degraded: (0..n_instances).map(|_| AtomicU32::new(0)).collect(),
            profile,
        })
    }

    pub fn n_instances(&self) -> usize {
        self.contention.len()
    }

    pub fn active_flows(&self, instance: usize) -> u32 {
        self.contention[instance].load(Ordering::Relaxed)
    }

    /// Transfer time of `bytes` to/from `instance` under current contention:
    /// fair-share bandwidth across (1 + active background flows).
    pub fn transfer_time(&self, instance: usize, bytes: usize) -> Duration {
        let flows = 1 + self.active_flows(instance) as u64;
        Duration::from_secs_f64(
            bytes as f64 * flows as f64 / self.profile.link_bandwidth,
        )
    }

    fn enter(&self, instance: usize) {
        self.contention[instance].fetch_add(1, Ordering::Relaxed);
    }

    fn leave(&self, instance: usize) {
        self.contention[instance].fetch_sub(1, Ordering::Relaxed);
    }

    /// Degrade `instance`'s link by pinning `flows` phantom background
    /// flows on it: transfers see `flows` extra fair-share contenders and
    /// the worker's head-of-line delay scales with them, exactly as if
    /// that many shuffles were stuck on the link. Replaces any previous
    /// degradation on the instance (set `flows = 0` to clear). The
    /// scriptable network-chaos primitive `FaultAction::DegradeLink`
    /// drives this.
    pub fn degrade_link(&self, instance: usize, flows: u32) {
        let prev = self.degraded[instance].swap(flows, Ordering::Relaxed);
        if flows >= prev {
            self.contention[instance].fetch_add(flows - prev, Ordering::Relaxed);
        } else {
            self.contention[instance].fetch_sub(prev - flows, Ordering::Relaxed);
        }
    }

    /// Clear any chaos-injected degradation on `instance`'s link (live
    /// shuffle flows are untouched).
    pub fn restore_link(&self, instance: usize) {
        self.degrade_link(instance, 0);
    }

    /// Phantom flows currently pinned on `instance` by chaos injection.
    pub fn degraded_flows(&self, instance: usize) -> u32 {
        self.degraded[instance].load(Ordering::Relaxed)
    }
}

/// Background-shuffle generator: keeps `concurrent` shuffles alive, each
/// between a random pair of instances, transferring 128-256 MB.
pub struct ShuffleGen {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ShuffleGen {
    pub fn start(
        net: Arc<Network>,
        concurrent: usize,
        time_scale: f64,
        seed: u64,
    ) -> ShuffleGen {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("shuffle-gen".into())
            .spawn(move || shuffle_loop(net, concurrent, time_scale, seed, stop2))
            .expect("spawn shuffle-gen");
        ShuffleGen { stop, handle: Some(handle) }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShuffleGen {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct ActiveShuffle {
    a: usize,
    b: usize,
    ends_at: std::time::Instant,
}

fn shuffle_loop(
    net: Arc<Network>,
    concurrent: usize,
    time_scale: f64,
    seed: u64,
    stop: Arc<AtomicBool>,
) {
    let mut rng = Pcg64::new(seed);
    let n = net.n_instances();
    if n < 2 || concurrent == 0 {
        return;
    }
    let mut active: Vec<ActiveShuffle> = Vec::with_capacity(concurrent);
    while !stop.load(Ordering::Relaxed) {
        let now = std::time::Instant::now();
        // Retire finished shuffles.
        active.retain(|s| {
            if s.ends_at <= now {
                net.leave(s.a);
                net.leave(s.b);
                false
            } else {
                true
            }
        });
        // Launch new ones to hold the target concurrency.
        while active.len() < concurrent {
            let pair = rng.choose_distinct(n, 2);
            let (a, b) = (pair[0], pair[1]);
            // 128-256 MB at the shuffle's fair share of link bandwidth.
            let bytes = rng.range_u64(128 << 20, 256 << 20) as f64;
            let secs = bytes / (1.5e9 / 8.0) * time_scale;
            net.enter(a);
            net.enter(b);
            active.push(ActiveShuffle {
                a,
                b,
                ends_at: now + Duration::from_secs_f64(secs),
            });
            log::trace!("shuffle {a}<->{b} for {secs:.2}s");
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    for s in active {
        net.leave(s.a);
        net.leave(s.b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hardware::GPU;

    #[test]
    fn contention_inflates_transfer() {
        let net = Network::new(4, &GPU);
        let base = net.transfer_time(0, 1 << 20);
        net.enter(0);
        net.enter(0);
        let contended = net.transfer_time(0, 1 << 20);
        assert!((contended.as_secs_f64() / base.as_secs_f64() - 3.0).abs() < 1e-6);
        net.leave(0);
        net.leave(0);
        assert_eq!(net.transfer_time(0, 1 << 20), base);
    }

    #[test]
    fn shuffle_gen_creates_contention_and_cleans_up() {
        let net = Network::new(8, &GPU);
        let gen = ShuffleGen::start(net.clone(), 3, 0.001, 42);
        // Give the scheduler a moment to start shuffles.
        std::thread::sleep(Duration::from_millis(50));
        let total: u32 = (0..8).map(|i| net.active_flows(i)).sum();
        assert_eq!(total, 6, "3 shuffles x 2 endpoints");
        gen.stop();
        let total: u32 = (0..8).map(|i| net.active_flows(i)).sum();
        assert_eq!(total, 0, "all flows released on stop");
    }

    #[test]
    fn degrade_restore_inflates_and_clears() {
        let net = Network::new(4, &GPU);
        let base = net.transfer_time(1, 1 << 20);
        net.degrade_link(1, 8);
        assert_eq!(net.active_flows(1), 8);
        assert_eq!(net.degraded_flows(1), 8);
        let degraded = net.transfer_time(1, 1 << 20);
        assert!((degraded.as_secs_f64() / base.as_secs_f64() - 9.0).abs() < 1e-6);
        // Re-degrading replaces, never stacks.
        net.degrade_link(1, 3);
        assert_eq!(net.active_flows(1), 3);
        // Restore clears chaos flows but leaves live shuffle flows alone.
        net.enter(1);
        net.restore_link(1);
        assert_eq!(net.active_flows(1), 1);
        assert_eq!(net.degraded_flows(1), 0);
        net.leave(1);
        assert_eq!(net.transfer_time(1, 1 << 20), base);
    }

    #[test]
    fn zero_concurrent_is_noop() {
        let net = Network::new(4, &GPU);
        let gen = ShuffleGen::start(net.clone(), 0, 1.0, 1);
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!((0..4).map(|i| net.active_flows(i)).sum::<u32>(), 0);
        gen.stop();
    }
}

//! # ParM: coding-based resilience for ML prediction serving
//!
//! A full-system reproduction of *"Parity Models: A General Framework for
//! Coding-Based Resilience in ML Inference"* (Kosaian, Rashmi,
//! Venkataraman, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time, Python)**: Pallas kernels + JAX models, trained
//!   and AOT-lowered to HLO text by `python/compile/aot.py`;
//! - **L3 (this crate)**: a Clipper-style prediction-serving coordinator
//!   with ParM — encoder, parity models, decoder — as a first-class
//!   redundancy scheme, running the AOT artifacts via PJRT (feature
//!   `pjrt`; a deterministic synthetic backend otherwise) with Python
//!   never on the request path.
//!
//! The serving surface is a session API:
//! [`coordinator::session::ServiceBuilder`] assembles the simulated
//! cluster (pools, network, faults, tenancy, shuffles) from a
//! [`coordinator::service::ServiceConfig`];
//! [`coordinator::session::ServiceHandle`] then serves live traffic —
//! `submit` / `poll` / `drain` / `shutdown`. Redundancy strategies plug
//! in through the [`coordinator::scheme::RedundancyScheme`] trait (ParM
//! plus the paper's four baselines ship as implementations).
//! [`coordinator::service::Service::run`] remains as the one-shot
//! open-loop experiment shim used by the paper-figure harnesses in
//! [`experiments`].
//!
//! For concurrent traffic, the multi-client frontend
//! ([`coordinator::frontend::ServingFrontend`]) multiplexes any number of
//! cloneable [`coordinator::frontend::ServiceClient`]s onto one session,
//! with admission control
//! ([`coordinator::frontend::AdmissionPolicy`]) at `submit`, per-client
//! accounting, and live windowed metrics
//! ([`coordinator::metrics::LatencyWindow`]) on every surface. At fleet
//! scale, [`coordinator::shards::ShardedFrontend`] routes clients over N
//! independent sessions (consistent hashing, per-shard fault domains),
//! and [`coordinator::shards::CrossShardFrontend`] stripes each coding
//! group *across* those domains with a shared parity pool
//! ([`coordinator::cross_shard`]), so even the loss of an entire shard
//! decodes like a single-instance failure.
//!
//! Every tier publishes live metrics into one fleet-wide
//! [`telemetry::Registry`] (wait-free counters/gauges/summaries),
//! exported as Prometheus text over TCP ([`telemetry::Exporter`];
//! `parm serve --metrics-addr`), streamed as JSON snapshots
//! ([`telemetry::SnapshotLog`]; `--metrics-log`), and sampled into
//! bench time-series ([`telemetry::series`]) — all strictly
//! non-blocking for the serving path.
//!
//! Orientation: the top-level `README.md` covers the what and the
//! quickstart; `docs/ARCHITECTURE.md` maps every thread and channel from
//! builder to completion fan-out.

pub mod artifacts;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod util;
pub mod workload;

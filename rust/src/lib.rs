//! # ParM: coding-based resilience for ML prediction serving
//!
//! A full-system reproduction of *"Parity Models: A General Framework for
//! Coding-Based Resilience in ML Inference"* (Kosaian, Rashmi,
//! Venkataraman, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time, Python)**: Pallas kernels + JAX models, trained
//!   and AOT-lowered to HLO text by `python/compile/aot.py`;
//! - **L3 (this crate)**: a Clipper-style prediction-serving coordinator
//!   with ParM — encoder, parity models, decoder — as a first-class
//!   redundancy scheme, running the AOT artifacts via PJRT with Python
//!   never on the request path.
//!
//! Start at [`coordinator::service::Service`] for the serving loop, or
//! [`experiments`] for the paper-figure harnesses.

pub mod artifacts;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod workload;

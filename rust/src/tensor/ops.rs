//! Hot-path tensor ops for the ParM encoder/decoder.
//!
//! These run on the frontend for every coding group, so they are written as
//! contiguous-slice loops (auto-vectorized by LLVM) with no per-element
//! bounds checks in the inner loops. Semantics are pinned to the Python
//! build-time encoders (`python/compile/encoders.py`) by unit tests and by
//! the end-to-end accuracy experiments (a semantic mismatch between the two
//! sides would destroy reconstruction accuracy, which the experiments would
//! surface immediately).

use super::{Tensor, TensorError};

/// `acc += x` elementwise.
pub fn add_assign(acc: &mut Tensor, x: &Tensor) -> Result<(), TensorError> {
    if acc.shape() != x.shape() {
        return Err(TensorError::Incompatible(
            acc.shape().to_vec(),
            x.shape().to_vec(),
        ));
    }
    let a = acc.data_mut();
    let b = x.data();
    for i in 0..a.len() {
        a[i] += b[i];
    }
    Ok(())
}

/// `acc += w * x` elementwise (r > 1 parity weights).
pub fn add_scaled_assign(acc: &mut Tensor, x: &Tensor, w: f32) -> Result<(), TensorError> {
    if acc.shape() != x.shape() {
        return Err(TensorError::Incompatible(
            acc.shape().to_vec(),
            x.shape().to_vec(),
        ));
    }
    let a = acc.data_mut();
    let b = x.data();
    for i in 0..a.len() {
        a[i] += w * b[i];
    }
    Ok(())
}

/// `acc -= x` elementwise (the subtraction decoder).
pub fn sub_assign(acc: &mut Tensor, x: &Tensor) -> Result<(), TensorError> {
    if acc.shape() != x.shape() {
        return Err(TensorError::Incompatible(
            acc.shape().to_vec(),
            x.shape().to_vec(),
        ));
    }
    let a = acc.data_mut();
    let b = x.data();
    for i in 0..a.len() {
        a[i] -= b[i];
    }
    Ok(())
}

/// Weighted sum of equal-shaped tensors: `sum_i w_i * xs[i]`.
pub fn weighted_sum(xs: &[&Tensor], weights: &[f32]) -> Result<Tensor, TensorError> {
    assert_eq!(xs.len(), weights.len());
    assert!(!xs.is_empty());
    let mut acc = Tensor::zeros(xs[0].shape().to_vec());
    for (x, &w) in xs.iter().zip(weights) {
        add_scaled_assign(&mut acc, x, w)?;
    }
    Ok(acc)
}

/// Area-average downsample of an (H, W, C) tensor by integer factors.
/// Matches `python/compile/encoders.py::downsample_np` exactly.
pub fn resize_area(x: &Tensor, out_h: usize, out_w: usize) -> Result<Tensor, TensorError> {
    let s = x.shape();
    if s.len() != 3 {
        return Err(TensorError::Invalid {
            op: "resize_area",
            msg: format!("need (H, W, C), got {s:?}"),
        });
    }
    let (h, w, c) = (s[0], s[1], s[2]);
    if out_h == 0 || out_w == 0 || h % out_h != 0 || w % out_w != 0 {
        return Err(TensorError::Invalid {
            op: "resize_area",
            msg: format!("{h}x{w} not divisible into {out_h}x{out_w}"),
        });
    }
    let (fh, fw) = (h / out_h, w / out_w);
    let scale = 1.0 / (fh * fw) as f32;
    let src = x.data();
    let mut out = vec![0.0f32; out_h * out_w * c];
    for oy in 0..out_h {
        for ox in 0..out_w {
            let obase = (oy * out_w + ox) * c;
            for iy in 0..fh {
                let row = ((oy * fh + iy) * w + ox * fw) * c;
                for ix in 0..fw {
                    let ibase = row + ix * c;
                    for ch in 0..c {
                        out[obase + ch] += src[ibase + ch];
                    }
                }
            }
        }
    }
    for v in &mut out {
        *v *= scale;
    }
    Tensor::new(vec![out_h, out_w, c], out)
}

/// Concatenate (H, W, C) tensors vertically (axis 0).
pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor, TensorError> {
    assert!(!parts.is_empty());
    let s0 = parts[0].shape().to_vec();
    let mut total_h = 0;
    for p in parts {
        let s = p.shape();
        if s.len() != 3 || s[1] != s0[1] || s[2] != s0[2] {
            return Err(TensorError::Incompatible(s0, s.to_vec()));
        }
        total_h += s[0];
    }
    let mut data = Vec::with_capacity(total_h * s0[1] * s0[2]);
    for p in parts {
        data.extend_from_slice(p.data());
    }
    Tensor::new(vec![total_h, s0[1], s0[2]], data)
}

/// Concatenate (H, W, C) tensors horizontally (axis 1). All must share H, C.
pub fn concat_cols(parts: &[Tensor]) -> Result<Tensor, TensorError> {
    assert!(!parts.is_empty());
    let s0 = parts[0].shape().to_vec();
    let h = s0[0];
    let c = s0[2];
    let mut total_w = 0;
    for p in parts {
        let s = p.shape();
        if s.len() != 3 || s[0] != h || s[2] != c {
            return Err(TensorError::Incompatible(s0, s.to_vec()));
        }
        total_w += s[1];
    }
    let mut data = vec![0.0f32; h * total_w * c];
    for y in 0..h {
        let mut xoff = 0;
        for p in parts {
            let pw = p.shape()[1];
            let src = &p.data()[y * pw * c..(y + 1) * pw * c];
            let dst = &mut data[(y * total_w + xoff) * c..(y * total_w + xoff + pw) * c];
            dst.copy_from_slice(src);
            xoff += pw;
        }
    }
    Tensor::new(vec![h, total_w, c], data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec()).unwrap()
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut acc = t(&[4], &[1., 2., 3., 4.]);
        let x = t(&[4], &[0.5, 0.5, 0.5, 0.5]);
        add_assign(&mut acc, &x).unwrap();
        assert_eq!(acc.data(), &[1.5, 2.5, 3.5, 4.5]);
        sub_assign(&mut acc, &x).unwrap();
        assert_eq!(acc.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut acc = Tensor::zeros(vec![3]);
        let x = Tensor::zeros(vec![4]);
        assert!(add_assign(&mut acc, &x).is_err());
        assert!(sub_assign(&mut acc, &x).is_err());
    }

    #[test]
    fn weighted_sum_r2_weights() {
        let a = t(&[2], &[1., 2.]);
        let b = t(&[2], &[10., 20.]);
        let s = weighted_sum(&[&a, &b], &[1.0, 2.0]).unwrap();
        assert_eq!(s.data(), &[21., 42.]);
    }

    #[test]
    fn resize_area_2x() {
        // 2x2 -> 1x1 average, single channel.
        let x = t(&[2, 2, 1], &[1., 2., 3., 4.]);
        let y = resize_area(&x, 1, 1).unwrap();
        assert_eq!(y.data(), &[2.5]);
        // 4x4 -> 2x2, values laid out so each quadrant is constant.
        let mut data = vec![0.0; 16];
        for y_ in 0..4 {
            for x_ in 0..4 {
                data[y_ * 4 + x_] = ((y_ / 2) * 2 + x_ / 2) as f32;
            }
        }
        let x = t(&[4, 4, 1], &data);
        let y = resize_area(&x, 2, 2).unwrap();
        assert_eq!(y.data(), &[0., 1., 2., 3.]);
    }

    #[test]
    fn resize_area_multichannel_independent() {
        // 2 channels interleaved: averages must not mix channels.
        let x = t(&[2, 2, 2], &[1., 10., 2., 20., 3., 30., 4., 40.]);
        let y = resize_area(&x, 1, 1).unwrap();
        assert_eq!(y.data(), &[2.5, 25.0]);
    }

    #[test]
    fn resize_rejects_non_divisible() {
        let x = Tensor::zeros(vec![5, 4, 1]);
        assert!(resize_area(&x, 2, 2).is_err());
    }

    #[test]
    fn concat_rows_and_cols() {
        let a = t(&[1, 2, 1], &[1., 2.]);
        let b = t(&[1, 2, 1], &[3., 4.]);
        let v = concat_rows(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(v.shape(), &[2, 2, 1]);
        assert_eq!(v.data(), &[1., 2., 3., 4.]);
        let h = concat_cols(&[a, b]).unwrap();
        assert_eq!(h.shape(), &[1, 4, 1]);
        assert_eq!(h.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn concat_cols_interleaves_rows() {
        let a = t(&[2, 1, 1], &[1., 3.]);
        let b = t(&[2, 1, 1], &[2., 4.]);
        let h = concat_cols(&[a, b]).unwrap();
        assert_eq!(h.shape(), &[2, 2, 1]);
        assert_eq!(h.data(), &[1., 2., 3., 4.]);
    }
}

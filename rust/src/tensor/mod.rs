//! Row-major f32 tensor used on the request path.
//!
//! Deliberately minimal: queries and predictions are dense f32 arrays, and
//! the only math the coordinator does on them is the ParM encoder (adds,
//! scales, area-downsampling, tiling) and decoder (subtraction) — everything
//! else happens inside the PJRT executables. Hot-path ops are written as
//! straight contiguous-slice loops that LLVM auto-vectorizes.

pub mod ops;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

#[derive(Debug, thiserror::Error)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements, got {actual}")]
    ShapeMismatch { shape: Vec<usize>, expected: usize, actual: usize },
    #[error("incompatible shapes: {0:?} vs {1:?}")]
    Incompatible(Vec<usize>, Vec<usize>),
    #[error("invalid {op}: {msg}")]
    Invalid { op: &'static str, msg: String },
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != data.len() {
            return Err(TensorError::ShapeMismatch {
                shape,
                expected,
                actual: data.len(),
            });
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn filled(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != self.data.len() {
            return Err(TensorError::ShapeMismatch {
                shape,
                expected,
                actual: self.data.len(),
            });
        }
        self.shape = shape;
        Ok(self)
    }

    /// Split a batched tensor (leading dim = batch) into per-sample tensors.
    pub fn unbatch(&self) -> Vec<Tensor> {
        assert!(!self.shape.is_empty(), "unbatch of scalar");
        let b = self.shape[0];
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let stride: usize = inner.iter().product();
        (0..b)
            .map(|i| Tensor {
                shape: inner.clone(),
                data: self.data[i * stride..(i + 1) * stride].to_vec(),
            })
            .collect()
    }

    /// Stack per-sample tensors into a batch (leading dim = len).
    pub fn batch(samples: &[Tensor]) -> Result<Tensor, TensorError> {
        assert!(!samples.is_empty());
        let inner = samples[0].shape.clone();
        let mut data = Vec::with_capacity(samples.len() * samples[0].len());
        for s in samples {
            if s.shape != inner {
                return Err(TensorError::Incompatible(inner, s.shape.clone()));
            }
            data.extend_from_slice(&s.data);
        }
        let mut shape = vec![samples.len()];
        shape.extend_from_slice(&inner);
        Ok(Tensor { shape, data })
    }

    /// Index of the maximum element (argmax over the flat data).
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in self.data.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        best
    }

    /// Indices of the top-n elements, descending.
    pub fn top_n(&self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.data.len()).collect();
        idx.sort_unstable_by(|&a, &b| {
            self.data[b].partial_cmp(&self.data[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn batch_unbatch_roundtrip() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]).unwrap();
        let batched = Tensor::batch(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(batched.shape(), &[2, 2, 2]);
        let back = batched.unbatch();
        assert_eq!(back, vec![a, b]);
    }

    #[test]
    fn batch_rejects_mixed_shapes() {
        let a = Tensor::zeros(vec![2]);
        let b = Tensor::zeros(vec![3]);
        assert!(Tensor::batch(&[a, b]).is_err());
    }

    #[test]
    fn argmax_and_topn() {
        let t = Tensor::new(vec![5], vec![0.1, 0.9, 0.3, 0.9, 0.05]).unwrap();
        assert_eq!(t.argmax(), 1);
        assert_eq!(t.top_n(3), vec![1, 3, 2]);
    }

    #[test]
    fn reshape() {
        let t = Tensor::zeros(vec![2, 6]);
        let t = t.reshape(vec![3, 4]).unwrap();
        assert_eq!(t.shape(), &[3, 4]);
        assert!(t.reshape(vec![5]).is_err());
    }
}

//! `parm` CLI: the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   list                      — show artifact inventory
//!   accuracy                  — degraded-mode accuracy for one config
//!   serve                     — run the serving loop at a rate and report
//!                               (--clients N > 1 serves N concurrent
//!                               submitters through the multi-client
//!                               frontend with --admission control;
//!                               --admin-socket PATH exposes the control
//!                               plane on a unix socket while serving;
//!                               --metrics-addr HOST:PORT serves Prometheus
//!                               text and --metrics-log PATH streams JSON
//!                               snapshots while serving)
//!   admin                     — drive a live fleet's control plane over
//!                               its admin socket (status, drain, restore,
//!                               add-shard, remove-shard, set-admission,
//!                               telemetry, recommend)
//!   replay                    — re-execute a serving-path journal recorded
//!                               with `serve --record` and verify it
//!                               (byte-identical re-encode, outcome totals;
//!                               --report folds in the trace diagnostics)
//!   trace                     — mine a journal into diagnostics: per-query
//!                               phase breakdowns, group-fate timelines,
//!                               fault-impact windows (--json machine
//!                               output, --chrome OUT.json Perfetto export)
//!   mine                      — reconstruct a replayable workload trace
//!                               (arrivals + client attribution) from a
//!                               journal; replay it with `serve --trace`
//!   table1                    — the toy coded-computation example
//!
//! Every paper figure has a dedicated bench (`cargo bench --bench …`);
//! this binary is the interactive/manual entry point. All serving
//! subcommands are clients of the coordinator's session API
//! (`ServiceBuilder`/`ServiceHandle`, see `coordinator::session`).

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::AdmissionPolicy;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedFrontend};
use parm::experiments::{accuracy, latency, table1};
use parm::util::cli::Cli;
use parm::workload::QuerySource;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();
    match cmd {
        "list" => cmd_list(),
        "accuracy" => cmd_accuracy(rest),
        "serve" => cmd_serve(rest),
        "admin" => cmd_admin(rest),
        "experiment" => cmd_experiment(rest),
        "replay" => cmd_replay(rest),
        "trace" => cmd_trace(rest),
        "mine" => cmd_mine(rest),
        "table1" => cmd_table1(),
        _ => {
            println!(
                "parm — Parity Models prediction serving\n\n\
                 usage: parm <list|accuracy|serve|admin|experiment|replay|trace|mine|table1> \
                 [options]\n\
                 run `parm <cmd> --help` for per-command options"
            );
            Ok(())
        }
    }
}

fn cmd_list() -> anyhow::Result<()> {
    let m = Manifest::load_default()?;
    println!(
        "artifacts at {} ({} models, {} datasets{})",
        m.dir.display(),
        m.models.len(),
        m.datasets.len(),
        if m.fast_mode { ", FAST build" } else { "" }
    );
    println!("\n{:<44} {:>6} {:>3} {:>8} {:>8}", "model", "role", "k", "enc", "metric");
    for model in &m.models {
        println!(
            "{:<44} {:>6} {:>3} {:>8} {:>8.3}",
            model.name, model.role, model.k, model.encoder, model.train_metric
        );
    }
    println!("\ndatasets:");
    for d in &m.datasets {
        println!(
            "  {:<16} {:<9} classes={:<4} shape={:?} n_test={}",
            d.name, d.task, d.num_classes, d.input_shape, d.n_test
        );
    }
    Ok(())
}

fn cmd_accuracy(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("parm accuracy", "degraded-mode accuracy for one configuration")
        .opt("dataset", "synthvision10", "dataset name")
        .opt("arch", "microresnet", "architecture")
        .opt("k", "2", "queries per coding group")
        .opt("encoder", "sum", "encoder: sum | concat")
        .opt("seed", "7", "stripe-sampling seed");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let m = Manifest::load_default()?;
    let dep = m.deployed(a.get("dataset"), a.get("arch"))?;
    let par = m.parity(a.get("dataset"), a.get("arch"), a.get_usize("k"), a.get("encoder"), 0)?;
    let r = accuracy::evaluate(&m, dep, par, a.get_u64("seed"))?;
    println!(
        "{} / {} k={} enc={} ({} stripes, metric {})",
        r.dataset, r.arch, r.k, r.encoder, r.n_stripes, r.metric
    );
    println!("  A_a (available)        = {:.4}", r.available);
    println!("  A_d (ParM degraded)    = {:.4}", r.degraded);
    println!("  A_d (default baseline) = {:.4}", r.default_baseline);
    println!("  A_o at f_u=0.05        = {:.4}", r.overall(0.05));
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("parm serve", "run the serving loop and report latency")
        .opt(
            "mode",
            "parm",
            "parm | none | equal-resources | approx-backup | replication | rateless \
             | cross-shard (needs --shards >= k)",
        )
        .opt("k", "2", "coding-group size")
        .opt(
            "redundancy-min",
            "1",
            "rateless/cross-shard: parity floor per coding group",
        )
        .opt(
            "redundancy-max",
            "2",
            "rateless/cross-shard: parity ceiling per coding group (pools are \
             provisioned for this)",
        )
        .opt(
            "predictor-halflife-ms",
            "1000",
            "rateless/cross-shard: straggler-predictor evidence half-life",
        )
        .opt("cluster", "gpu", "hardware profile: gpu | cpu")
        .opt("rate", "0", "query rate qps (0 = 60% utilization)")
        .opt("queries", "20000", "number of queries")
        .opt("batch", "1", "batch size")
        .opt("shuffles", "4", "concurrent background shuffles")
        .opt("seed", "49374", "rng seed")
        .opt(
            "clients",
            "1",
            "concurrent client threads (>1 serves via the multi-client frontend)",
        )
        .opt("shards", "1", "serving shards (>1 serves via the consistent-hash sharded tier)")
        .opt("vnodes", "64", "virtual nodes per shard on the hash ring")
        .opt("global-backlog", "0", "fleet-wide offered-load cap over all shards (0 = none)")
        .opt(
            "admin-socket",
            "",
            "expose the control plane on this unix socket while serving \
             (sharded/cross-shard tiers; drive it with `parm admin`)",
        )
        .opt(
            "admission",
            "unbounded",
            "admission policy: unbounded | reject-above | block | slo-aware",
        )
        .opt("admission-backlog", "64", "load limit for reject-above / block / slo-aware")
        .opt("admission-timeout-ms", "50", "max wait for block admission")
        .opt(
            "slo-ms",
            "0",
            "SLO in ms (0 = none; stragglers past it get default predictions; \
             slo-aware admission sheds at this p99)",
        )
        .opt(
            "scenario",
            "",
            "replace live Poisson pacing with a named workload scenario: \
             poisson | diurnal | flash-crowd | zipf | multi-tenant-burst",
        )
        .opt(
            "trace",
            "",
            "replay a recorded workload trace file (`parm mine` output or a \
             saved scenario) instead of live pacing; excludes --scenario",
        )
        .opt(
            "kill-shard",
            "",
            "MS:SHARD — kill every instance of SHARD (via the control plane) \
             MS milliseconds into the run; needs --shards > 1",
        )
        .opt(
            "record",
            "",
            "record the serving-path event journal to this file \
             (re-execute and verify it with `parm replay`)",
        )
        .opt(
            "metrics-addr",
            "",
            "serve Prometheus text metrics on this HOST:PORT while serving \
             (port 0 picks a free one; scrape with curl)",
        )
        .opt(
            "metrics-log",
            "",
            "append one JSON metrics snapshot per interval to this file",
        )
        .opt("metrics-interval-ms", "1000", "snapshot interval for --metrics-log")
        .flag("tenancy", "enable light multitenancy instead of shuffles");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let m = Manifest::load_default()?;
    let profile = hardware::by_name(a.get("cluster"))
        .ok_or_else(|| anyhow::anyhow!("unknown cluster {:?}", a.get("cluster")))?;
    let k = a.get_usize("k");
    let batch = a.get_usize("batch");
    let with_approx = a.get("mode") == "approx-backup";
    // Rateless and cross-shard provision parity pools for the ceiling,
    // so they need redundancy-max parity executables; other modes need
    // one.
    let parities = match a.get("mode") {
        "rateless" | "cross-shard" => a.get_usize("redundancy-max").max(1),
        _ => 1,
    };
    let models = latency::load_models(&m, batch, k, parities, with_approx)?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;

    let mode = match a.get("mode") {
        "parm" => Mode::Parm { k, encoders: vec![Encoder::sum(k)] },
        "none" => Mode::NoRedundancy,
        "equal-resources" => Mode::EqualResources { k },
        "approx-backup" => Mode::ApproxBackup { k },
        "replication" => Mode::Replication { copies: 2 },
        "rateless" | "cross-shard" => {
            let r_min = a.get_usize("redundancy-min");
            let r_max = a.get_usize("redundancy-max");
            if !(1..=r_max).contains(&r_min) || r_max > k {
                anyhow::bail!("need 1 <= --redundancy-min <= --redundancy-max <= k");
            }
            let halflife = a.get_duration_ms("predictor-halflife-ms");
            if halflife.is_zero() {
                anyhow::bail!("--predictor-halflife-ms must be > 0");
            }
            if a.get("mode") == "rateless" {
                Mode::Rateless { k, r_min, r_max, halflife }
            } else {
                Mode::CrossShard { k, r_min, r_max, halflife }
            }
        }
        other => anyhow::bail!("unknown mode {other:?}"),
    };
    let mut cfg = ServiceConfig::defaults(mode, profile);
    cfg.batch_size = batch;
    cfg.shuffles = if a.has_flag("tenancy") { 0 } else { a.get_usize("shuffles") };
    cfg.light_tenancy = a.has_flag("tenancy");
    cfg.seed = a.get_u64("seed");
    let slo_ms = a.get_f64("slo-ms");
    if slo_ms > 0.0 {
        cfg.slo = Some(a.get_duration_ms("slo-ms"));
    }
    // Same validation the JSON config path (config/mod.rs) enforces.
    let backlog = a.get_usize("admission-backlog");
    cfg.admission = match a.get("admission") {
        "unbounded" => AdmissionPolicy::Unbounded,
        "reject-above" | "block" | "slo-aware" => {
            if backlog == 0 {
                anyhow::bail!("--admission-backlog must be >= 1");
            }
            match a.get("admission") {
                "reject-above" => AdmissionPolicy::RejectAbove { backlog },
                "slo-aware" => {
                    if slo_ms <= 0.0 {
                        anyhow::bail!("--admission slo-aware needs --slo-ms > 0");
                    }
                    AdmissionPolicy::SloAware { p99: a.get_duration_ms("slo-ms"), backlog }
                }
                _ => {
                    let timeout = a.get_duration_ms("admission-timeout-ms");
                    if timeout.is_zero() {
                        anyhow::bail!("--admission-timeout-ms must be > 0");
                    }
                    AdmissionPolicy::Block { backlog, timeout }
                }
            }
        }
        other => anyhow::bail!("unknown admission policy {other:?}"),
    };

    let mut rate = a.get_f64("rate");
    if rate == 0.0 {
        let probe = parm::tensor::Tensor::batch(
            &std::iter::repeat(source.queries[0].clone()).take(batch).collect::<Vec<_>>(),
        )?;
        let mean = parm::coordinator::service::measure_service(&models.deployed, &probe, 20);
        rate = 0.6 * profile.default_m as f64 / mean.as_secs_f64();
    }
    let clients = a.get_usize("clients").max(1);
    let shards = a.get_usize("shards");
    let admin_socket = match a.get("admin-socket") {
        "" => None,
        path => Some(path.to_string()),
    };
    let record = match a.get("record") {
        "" => None,
        path => Some(path.to_string()),
    };
    // Metrics export rides on the run's registry (cfg.telemetry), which
    // every tier — session, frontend, shards, control plane — publishes
    // into. The guards stay alive for the whole serve and stop on drop.
    let metrics_interval = a.get_duration_ms("metrics-interval-ms");
    let _metrics = start_metrics(
        &cfg.telemetry,
        match a.get("metrics-addr") {
            "" => None,
            addr => Some(addr),
        },
        match a.get("metrics-log") {
            "" => None,
            path => Some(path),
        },
        metrics_interval,
    )?;
    if record.is_some() {
        // Arm the serving-path journal before any tier spawns so the
        // recorder handle propagates to every shard session.
        cfg.recorder = parm::coordinator::journal::Recorder::start(
            cfg.seed,
            a.get("mode"),
            shards.max(1) as u64,
        );
    }
    let drive = match (a.get("scenario"), a.get("trace")) {
        ("", "") => Drive::Paced { n: a.get_u64("queries"), rate, clients },
        (name, "") => {
            let trace = parm::workload::scenario::generate(
                name,
                cfg.seed,
                a.get_u64("queries") as usize,
                rate,
                source.queries.len(),
            )
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario {name:?}; the catalogue has: {}",
                    parm::workload::scenario::names().join(", ")
                )
            })?;
            Drive::Trace { name: name.to_string(), trace }
        }
        ("", path) => {
            let trace = parm::workload::trace::Trace::load(path)
                .map_err(|e| anyhow::anyhow!("load trace {path}: {e}"))?;
            anyhow::ensure!(!trace.is_empty(), "trace {path} has no arrivals");
            Drive::Trace { name: path.to_string(), trace }
        }
        _ => anyhow::bail!("--scenario and --trace are mutually exclusive"),
    };
    let kill = match a.get("kill-shard") {
        "" => None,
        spec => {
            let (ms, shard) = spec
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--kill-shard wants MS:SHARD, e.g. 300:1"))?;
            let ms: u64 =
                ms.parse().map_err(|e| anyhow::anyhow!("--kill-shard delay {ms:?}: {e}"))?;
            let victim: usize =
                shard.parse().map_err(|e| anyhow::anyhow!("--kill-shard shard {shard:?}: {e}"))?;
            anyhow::ensure!(shards > 1, "--kill-shard needs the sharded tier; pass --shards > 1");
            anyhow::ensure!(victim < shards, "--kill-shard shard {victim} >= --shards {shards}");
            Some((ms, victim))
        }
    };
    if matches!(cfg.mode, Mode::CrossShard { .. }) {
        if shards < k {
            anyhow::bail!(
                "--mode cross-shard stripes k={k} slots over distinct shards; \
                 pass --shards >= {k}"
            );
        }
        let spec = ShardSpec {
            shards,
            vnodes: a.get_usize("vnodes"),
            global_backlog: match a.get_usize("global-backlog") {
                0 => None,
                n => Some(n),
            },
        };
        return serve_cross_shard(
            cfg,
            spec,
            &models,
            &source,
            &drive,
            admin_socket.as_deref(),
            record.as_deref(),
            kill,
        );
    }
    if shards > 1 {
        let spec = ShardSpec {
            shards,
            vnodes: a.get_usize("vnodes"),
            global_backlog: match a.get_usize("global-backlog") {
                0 => None,
                n => Some(n),
            },
        };
        return serve_sharded(
            cfg,
            spec,
            &models,
            &source,
            &drive,
            admin_socket.as_deref(),
            record.as_deref(),
            kill,
        );
    }
    if admin_socket.is_some() {
        anyhow::bail!("--admin-socket needs the sharded tier; pass --shards > 1");
    }
    // A bare session enforces no admission policy (see ServiceConfig
    // docs), so any bounding policy routes through the frontend — even
    // with a single client. Recording and scenario traces also route
    // through the frontend: it exposes the run record the journal
    // footer needs and replays arbitrary arrival schedules.
    if clients == 1
        && cfg.admission == AdmissionPolicy::Unbounded
        && record.is_none()
        && matches!(drive, Drive::Paced { .. })
    {
        let row =
            latency::run_point(&cfg, &models, &source, a.get_u64("queries"), rate, a.get("mode"))?;
        println!("{}", parm::experiments::latency::LatencyRow::header());
        println!("{}", row.line());
        return Ok(());
    }
    serve_multi_client(cfg, &models, &source, &drive, record.as_deref())
}

/// How a serve subcommand offers load: `clients` paced-Poisson submitter
/// threads splitting `n` and `rate` evenly, or a scenario trace replayed
/// on one open-loop submitter at its recorded offsets.
enum Drive {
    Paced { n: u64, rate: f64, clients: usize },
    Trace { name: String, trace: parm::workload::trace::Trace },
}

impl Drive {
    fn describe(&self) -> String {
        match self {
            Drive::Paced { n, rate, clients } => {
                format!("{n} queries from {clients} paced clients at {rate:.0} qps total")
            }
            Drive::Trace { name, trace } => format!(
                "{} arrivals from scenario {name:?} (nominal {:.0} qps, CV\u{b2} {:.2})",
                trace.len(),
                trace.rate_qps,
                trace.stats().1,
            ),
        }
    }
}

/// Dispatch a [`Drive`] through whichever client type the serving tier
/// mints.
fn drive_clients<C: PacedClient>(
    drive: &Drive,
    seed: u64,
    source: &QuerySource,
    mut mint: impl FnMut() -> C,
) -> Vec<C> {
    match drive {
        Drive::Paced { n, rate, clients } => {
            drive_paced_clients(*n, *rate, *clients, seed, source, mint)
        }
        Drive::Trace { trace, .. } => vec![drive_trace_client(trace, source, mint())],
    }
}

/// Replay a trace's arrival schedule through one client: offer each
/// query at its recorded offset (open loop — arrivals never wait for
/// completions), then wait out everything that was accepted.
fn drive_trace_client<C: PacedClient>(
    trace: &parm::workload::trace::Trace,
    source: &QuerySource,
    client: C,
) -> C {
    use std::time::{Duration, Instant};
    let start = Instant::now();
    let mut accepted = 0u64;
    for (i, &offset) in trace.arrivals.iter().enumerate() {
        let due = start + Duration::from_secs_f64(offset.max(0.0));
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let query = &source.queries[trace.query_idx[i] % source.queries.len()];
        if client.offer(query.clone()) {
            accepted += 1;
        }
        client.sweep(); // keep the inbox from growing
    }
    while client.resolved() < accepted {
        if !client.wait_next(Duration::from_secs(10)) {
            break;
        }
    }
    client
}

/// The submit/poll/next/stats surface the paced CLI driver needs — the
/// seam that lets `serve` and `serve --shards` share one driver loop
/// instead of diverging copies.
trait PacedClient: Send + 'static {
    fn offer(&self, input: parm::tensor::Tensor) -> bool;
    fn sweep(&self);
    fn resolved(&self) -> u64;
    fn wait_next(&self, timeout: std::time::Duration) -> bool;
}

impl PacedClient for parm::coordinator::frontend::ServiceClient {
    fn offer(&self, input: parm::tensor::Tensor) -> bool {
        self.submit(input).is_ok()
    }
    fn sweep(&self) {
        let _ = self.poll();
    }
    fn resolved(&self) -> u64 {
        self.stats().resolved
    }
    fn wait_next(&self, timeout: std::time::Duration) -> bool {
        self.next(timeout).is_some()
    }
}

impl PacedClient for parm::coordinator::shards::ShardedClient {
    fn offer(&self, input: parm::tensor::Tensor) -> bool {
        self.submit(input).is_ok()
    }
    fn sweep(&self) {
        let _ = self.poll();
    }
    fn resolved(&self) -> u64 {
        self.stats().resolved
    }
    fn wait_next(&self, timeout: std::time::Duration) -> bool {
        self.next(timeout).is_some()
    }
}

/// Drive `clients` paced-Poisson submitter threads (splitting `n`
/// queries and `rate` evenly, remainder spread so exactly `n` are
/// offered), wait for everything each client was promised, and return
/// the clients for reporting.
fn drive_paced_clients<C: PacedClient>(
    n: u64,
    rate: f64,
    clients: usize,
    seed: u64,
    source: &QuerySource,
    mut mint: impl FnMut() -> C,
) -> Vec<C> {
    use parm::util::rng::Pcg64;
    use std::time::{Duration, Instant};

    let per = n / clients as u64;
    let rem = n % clients as u64;
    let per_rate = rate / clients as f64;
    let mut joins = Vec::new();
    for c in 0..clients {
        let quota = per + u64::from((c as u64) < rem);
        let client = mint();
        let queries = source.queries.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(seed ^ 0x5EED ^ (c as u64) << 17);
            let mut due = Instant::now();
            let mut accepted = 0u64;
            for i in 0..quota {
                due += Duration::from_secs_f64(rng.exponential(per_rate));
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if client.offer(queries[i as usize % queries.len()].clone()) {
                    accepted += 1;
                }
                client.sweep(); // keep inboxes from growing
            }
            // Wait for everything this client was promised.
            while client.resolved() < accepted {
                if !client.wait_next(Duration::from_secs(10)) {
                    break;
                }
            }
            client
        }));
    }
    joins.into_iter().map(|j| j.join().expect("client thread")).collect()
}

/// Drive `clients` concurrent submitter threads through the sharded tier
/// (`shards` independent sessions behind a consistent-hash router),
/// splitting `n` queries and `rate` evenly, then report per-client and
/// per-shard stats plus the merged fleet-wide run result.
fn serve_sharded(
    cfg: ServiceConfig,
    spec: ShardSpec,
    models: &parm::coordinator::service::ModelSet,
    source: &QuerySource,
    drive: &Drive,
    admin_socket: Option<&str>,
    record: Option<&str>,
    kill: Option<(u64, usize)>,
) -> anyhow::Result<()> {
    use parm::coordinator::control::{ControlPlane, Fleet, FleetRunResult};
    let seed = cfg.seed;
    let instances = cfg.m;
    let recorder = cfg.recorder.clone();
    let tier = ShardedFrontend::start(cfg, spec, models, &source.queries[0])?;
    println!("serving {} over {} shards", drive.describe(), tier.shards());
    let plane = std::sync::Arc::new(ControlPlane::new(Fleet::Sharded(tier)));
    // Fleet/per-shard windows refresh at scrape time, not on a poll loop.
    let _sampler = plane.register_sampler();
    let _admin = bind_admin(&plane, admin_socket)?;
    let killer = spawn_shard_killer(&plane, kill, instances);
    let done =
        drive_clients(drive, seed, source, || plane.client().expect("fleet is live"));
    if let Some(h) = killer {
        let _ = h.join();
    }
    println!(
        "{:<8} {:>6} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "client", "shard", "submitted", "resolved", "rejected", "p50(ms)", "p99(ms)"
    );
    for client in done {
        let st = client.stats();
        let w = client.window();
        println!(
            "{:<8} {:>6} {:>9} {:>9} {:>9} {:>10.3} {:>10.3}",
            client.id(),
            client.shard().map_or_else(|| "-".into(), |s| s.to_string()),
            st.submitted,
            st.resolved,
            st.rejected,
            w.p50_ms,
            w.p99_ms,
        );
    }
    for s in 0..plane.shards()? {
        println!("shard {s} window: {}", plane.shard_window(s)?.report("live"));
    }
    println!("fleet window:   {}", plane.window()?.report("merged"));
    let res = match plane.shutdown()? {
        FleetRunResult::Sharded(res) => res,
        FleetRunResult::CrossShard(_) => unreachable!("plane owns a sharded fleet"),
    };
    if let Some(path) = record {
        recorder.finish_to_file(path, &res.merged)?;
        println!(
            "journal: {} events to {path} — verify with `parm replay {path}`",
            recorder.events()
        );
    }
    for (s, r) in res.per_shard.iter().enumerate() {
        println!(
            "shard {s}: resolved={} rejected={} reconstructions={} dropped_jobs={}",
            r.metrics.total(),
            r.rejected,
            r.reconstructions,
            r.dropped_jobs
        );
    }
    let mut metrics = res.merged.metrics;
    println!("{}", metrics.report("fleet total"));
    println!(
        "wall={:.1}s reconstructions={} dropped_jobs={} rejected={}",
        res.merged.wall.as_secs_f64(),
        res.merged.reconstructions,
        res.merged.dropped_jobs,
        res.merged.rejected
    );
    Ok(())
}

/// Drive `clients` concurrent submitter threads through the cross-shard
/// coding tier (groups striped over distinct shards, shared parity
/// pool), then report per-client stats, the fleet coding telemetry, and
/// the merged run records.
fn serve_cross_shard(
    cfg: ServiceConfig,
    spec: ShardSpec,
    models: &parm::coordinator::service::ModelSet,
    source: &QuerySource,
    drive: &Drive,
    admin_socket: Option<&str>,
    record: Option<&str>,
    kill: Option<(u64, usize)>,
) -> anyhow::Result<()> {
    use parm::coordinator::control::{ControlPlane, Fleet, FleetRunResult};
    let seed = cfg.seed;
    let instances = cfg.m;
    let recorder = cfg.recorder.clone();
    let tier = CrossShardFrontend::start(cfg, spec, models, &source.queries[0])?;
    println!(
        "serving {} over {} shards (cross-shard coding groups; shared parity pools of {} \
         instances each)",
        drive.describe(),
        tier.shards(),
        tier.parity_pool_size(),
    );
    let plane = std::sync::Arc::new(ControlPlane::new(Fleet::CrossShard(tier)));
    // Fleet/per-shard windows refresh at scrape time, not on a poll loop.
    let _sampler = plane.register_sampler();
    let _admin = bind_admin(&plane, admin_socket)?;
    let killer = spawn_shard_killer(&plane, kill, instances);
    let done =
        drive_clients(drive, seed, source, || plane.client().expect("fleet is live"));
    if let Some(h) = killer {
        let _ = h.join();
    }
    // Tail groups get parity protection before the wait-out.
    plane.flush_open_groups()?;
    println!(
        "{:<8} {:>6} {:>9} {:>9} {:>9} {:>10} {:>10} {:>10}",
        "client", "shard", "submitted", "resolved", "rejected", "p50(ms)", "p99(ms)", "recovered"
    );
    for client in done {
        let st = client.stats();
        let w = client.window();
        println!(
            "{:<8} {:>6} {:>9} {:>9} {:>9} {:>10.3} {:>10.3} {:>10}",
            client.id(),
            client.shard().map_or_else(|| "-".into(), |s| s.to_string()),
            st.submitted,
            st.resolved,
            st.rejected,
            w.p50_ms,
            w.p99_ms,
            st.recovered,
        );
    }
    let t = plane.cross_telemetry()?.expect("plane owns a cross-shard fleet");
    println!(
        "coding: groups={} parity_jobs={} (overhead {:.3}) last_r={} recon={} \
         fleet_unavail={:.4}",
        t.groups_sealed,
        t.parity_jobs,
        if t.groups_sealed > 0 { t.parity_jobs as f64 / t.groups_sealed as f64 } else { 0.0 },
        t.last_r,
        t.reconstructions,
        t.fleet_unavailability
    );
    println!("fleet window:   {}", plane.window()?.report("merged"));
    let res = match plane.shutdown()? {
        FleetRunResult::CrossShard(res) => res,
        FleetRunResult::Sharded(_) => unreachable!("plane owns a cross-shard fleet"),
    };
    if let Some(path) = record {
        recorder.finish_to_file(path, &res.fleet.merged)?;
        println!(
            "journal: {} events to {path} — verify with `parm replay {path}`",
            recorder.events()
        );
    }
    for (s, r) in res.fleet.per_shard.iter().enumerate() {
        println!(
            "shard {s}: resolved={} rejected={} recovered={} dropped_jobs={}",
            r.metrics.total(),
            r.rejected,
            r.metrics.reconstructed,
            r.dropped_jobs
        );
    }
    for (ri, r) in res.parity.iter().enumerate() {
        println!(
            "parity pool r{ri}: parity_queries={} defaulted={} dropped_jobs={}",
            r.metrics.total(),
            r.metrics.defaulted,
            r.dropped_jobs
        );
    }
    let mut metrics = res.fleet.merged.metrics;
    println!("{}", metrics.report("fleet total"));
    println!(
        "wall={:.1}s cross-shard reconstructions={} rejected={}",
        res.fleet.merged.wall.as_secs_f64(),
        res.telemetry.reconstructions,
        res.fleet.merged.rejected
    );
    Ok(())
}

/// `--kill-shard MS:SHARD`: a timed whole-shard kill through the
/// control plane — every instance of the victim shard dies `MS`
/// milliseconds into the run, each kill recorded as a journal `Fault`
/// event by the shard's fault plan. The reproducible-chaos counterpart
/// to `parm admin`-driven kills, for recording fault-impact journals
/// from the CLI.
fn spawn_shard_killer(
    plane: &std::sync::Arc<parm::coordinator::control::ControlPlane>,
    kill: Option<(u64, usize)>,
    instances: usize,
) -> Option<std::thread::JoinHandle<()>> {
    let (after_ms, shard) = kill?;
    let plane = plane.clone();
    Some(std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(after_ms));
        let mut killed = 0usize;
        for i in 0..instances {
            if plane.kill_instance(shard, i).is_ok() {
                killed += 1;
            }
        }
        println!("chaos: killed {killed}/{instances} instances of shard {shard} at +{after_ms}ms");
    }))
}

/// Export guards for one serve run: the Prometheus endpoint and/or the
/// JSON snapshot log, both reading the run's registry. Dropping the
/// struct stops both.
struct MetricsGuards {
    _exporter: Option<parm::telemetry::Exporter>,
    _log: Option<parm::telemetry::SnapshotLog>,
}

/// Start whichever metrics outputs were requested (`None` flags are
/// skipped) and print where they landed.
fn start_metrics(
    registry: &parm::telemetry::Registry,
    addr: Option<&str>,
    log_path: Option<&str>,
    interval: std::time::Duration,
) -> anyhow::Result<MetricsGuards> {
    let exporter = match addr {
        Some(addr) => {
            let e = parm::telemetry::Exporter::bind(addr, registry.clone())?;
            println!("metrics endpoint at http://{}/metrics", e.local_addr());
            Some(e)
        }
        None => None,
    };
    let log = match log_path {
        Some(path) => {
            anyhow::ensure!(!interval.is_zero(), "--metrics-interval-ms must be > 0");
            let l = parm::telemetry::SnapshotLog::start(path, registry.clone(), interval)?;
            println!("metrics snapshots to {path} every {} ms", interval.as_millis());
            Some(l)
        }
        None => None,
    };
    Ok(MetricsGuards { _exporter: exporter, _log: log })
}

/// Bind the control-plane admin endpoint when a socket path was given.
/// The returned guard keeps the endpoint serving until it drops.
#[cfg(unix)]
fn bind_admin(
    plane: &std::sync::Arc<parm::coordinator::control::ControlPlane>,
    path: Option<&str>,
) -> anyhow::Result<Option<parm::coordinator::control::AdminServer>> {
    match path {
        Some(p) if !p.is_empty() => {
            let server = parm::coordinator::control::AdminServer::bind(p, plane.clone())?;
            println!("admin endpoint at {p} — drive it with `parm admin --socket {p} status`");
            Ok(Some(server))
        }
        _ => Ok(None),
    }
}

#[cfg(not(unix))]
fn bind_admin(
    _plane: &std::sync::Arc<parm::coordinator::control::ControlPlane>,
    path: Option<&str>,
) -> anyhow::Result<Option<()>> {
    match path {
        Some(p) if !p.is_empty() => {
            anyhow::bail!("--admin-socket {p:?} needs unix domain sockets")
        }
        _ => Ok(None),
    }
}

fn cmd_admin(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "parm admin",
        "drive a live fleet's control plane: parm admin --socket PATH \
         <status|telemetry|recommend|ping|drain|restore|add-shard|remove-shard|set-admission>",
    )
    .req("socket", "admin socket path (the serve side's --admin-socket)")
    .opt("shard", "", "shard index for drain / restore / remove-shard")
    .opt("policy", "", "set-admission: unbounded | reject-above | block | slo-aware")
    .opt("backlog", "", "set-admission: backlog bound")
    .opt("timeout-ms", "", "set-admission block: max wait before rejecting")
    .opt("slo-ms", "", "set-admission slo-aware: p99 shedding target");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let cmd = a
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("parm admin needs a command; run `parm admin --help`"))?;
    let mut req = parm::util::json::Json::obj().set("cmd", cmd);
    if !a.get("shard").is_empty() {
        req = req.set("shard", a.get_usize("shard"));
    }
    if !a.get("policy").is_empty() {
        req = req.set("policy", a.get("policy"));
    }
    if !a.get("backlog").is_empty() {
        req = req.set("backlog", a.get_usize("backlog"));
    }
    if !a.get("timeout-ms").is_empty() {
        req = req.set("timeout_ms", a.get_f64("timeout-ms"));
    }
    if !a.get("slo-ms").is_empty() {
        req = req.set("slo_ms", a.get_f64("slo-ms"));
    }
    let reply = admin_roundtrip(a.get("socket"), &req.to_string())?;
    println!("{reply}");
    let parsed = parm::util::json::Json::parse(&reply)?;
    if parsed.at(&["ok"]).as_bool() != Some(true) {
        anyhow::bail!(
            "command failed: {}",
            parsed.at(&["error"]).as_str().unwrap_or("unknown error")
        );
    }
    Ok(())
}

/// One request/response round-trip against the admin socket.
#[cfg(unix)]
fn admin_roundtrip(socket: &str, line: &str) -> anyhow::Result<String> {
    use std::io::{BufRead, BufReader, Write};
    let stream = std::os::unix::net::UnixStream::connect(socket).map_err(|e| {
        anyhow::anyhow!("connect {socket}: {e} (is `parm serve --admin-socket` running?)")
    })?;
    let mut writer = stream.try_clone()?;
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    if reply.trim().is_empty() {
        anyhow::bail!("server closed the connection without a reply");
    }
    Ok(reply.trim().to_string())
}

#[cfg(not(unix))]
fn admin_roundtrip(_socket: &str, _line: &str) -> anyhow::Result<String> {
    anyhow::bail!("parm admin needs unix domain sockets")
}

/// Drive `clients` concurrent submitter threads through the multi-client
/// frontend, splitting `n` queries and `rate` evenly, then report
/// per-client windowed stats and the session's run result.
fn serve_multi_client(
    cfg: ServiceConfig,
    models: &parm::coordinator::service::ModelSet,
    source: &QuerySource,
    drive: &Drive,
    record: Option<&str>,
) -> anyhow::Result<()> {
    let seed = cfg.seed;
    let recorder = cfg.recorder.clone();
    let frontend = parm::coordinator::session::ServiceBuilder::new(cfg)
        .serve(models, &source.queries[0])?;
    println!("serving {} (policy {:?})", drive.describe(), frontend.policy());
    let done = drive_clients(drive, seed, source, || frontend.client());
    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>10} {:>10} {:>9} {:>9}",
        "client", "submitted", "resolved", "rejected", "p50(ms)", "p99(ms)", "recovered", "default"
    );
    for client in done {
        let st = client.stats();
        let w = client.window();
        println!(
            "{:<8} {:>9} {:>9} {:>9} {:>10.3} {:>10.3} {:>9} {:>9}",
            client.id(), st.submitted, st.resolved, st.rejected, w.p50_ms, w.p99_ms,
            st.recovered, st.defaulted
        );
    }
    println!("\nfrontend window: {}", frontend.window().report("all-clients"));
    let res = frontend.shutdown()?;
    if let Some(path) = record {
        recorder.finish_to_file(path, &res)?;
        println!(
            "journal: {} events to {path} — verify with `parm replay {path}`",
            recorder.events()
        );
    }
    let mut metrics = res.metrics;
    println!("{}", metrics.report("run total"));
    println!(
        "wall={:.1}s reconstructions={} dropped_jobs={} rejected={}",
        res.wall.as_secs_f64(),
        res.reconstructions,
        res.dropped_jobs,
        res.rejected
    );
    Ok(())
}

fn cmd_experiment(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new("parm experiment", "run a JSON-defined experiment config")
        .req("config", "path to experiment config (see rust/src/config)");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let exp = parm::config::ExperimentConfig::from_file(a.get("config"))?;
    let m = Manifest::load_default()?;
    let (k, with_approx) = match &exp.service.mode {
        Mode::Parm { k, .. }
        | Mode::EqualResources { k }
        | Mode::Rateless { k, .. }
        | Mode::CrossShard { k, .. } => (*k, false),
        Mode::ApproxBackup { k } => (*k, true),
        _ => (2, false),
    };
    let r = match &exp.service.mode {
        Mode::Parm { encoders, .. } => encoders.len(),
        Mode::Rateless { r_max, .. } | Mode::CrossShard { r_max, .. } => *r_max,
        _ => 1,
    };
    let models = latency::load_models(&m, exp.service.batch_size, k, r, with_approx)?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;

    let mut cfg = exp.service.clone();
    cfg.fault_schedule = exp
        .faults
        .iter()
        .map(|f| {
            (
                f.instance,
                std::time::Duration::from_millis(f.at_ms),
                std::time::Duration::from_millis(f.for_ms),
            )
        })
        .collect();
    // JSON-configured metrics export rides the same registry path as
    // the serve flags (`metrics_addr` / `metrics_log` keys).
    let _metrics = start_metrics(
        &cfg.telemetry,
        exp.metrics_addr.as_deref(),
        exp.metrics_log.as_deref(),
        exp.metrics_interval,
    )?;
    let rate = if exp.rate_qps > 0.0 {
        exp.rate_qps
    } else {
        let probe = parm::tensor::Tensor::batch(
            &std::iter::repeat(source.queries[0].clone())
                .take(cfg.batch_size)
                .collect::<Vec<_>>(),
        )?;
        let mean = parm::coordinator::service::measure_service(&models.deployed, &probe, 20);
        exp.utilization * cfg.m as f64 / mean.as_secs_f64()
    };
    if matches!(cfg.mode, Mode::CrossShard { .. }) {
        // Config validation guarantees shards >= k for this mode.
        let drive = Drive::Paced { n: exp.queries, rate, clients: exp.shards.shards * 4 };
        return serve_cross_shard(
            cfg,
            exp.shards,
            &models,
            &source,
            &drive,
            exp.admin_socket.as_deref(),
            None,
            None,
        );
    }
    if exp.shards.shards > 1 {
        // Sharded experiments serve paced concurrent clients (4 per
        // shard) through the consistent-hash tier and report the merged
        // fleet record instead of a single-session latency row.
        let drive = Drive::Paced { n: exp.queries, rate, clients: exp.shards.shards * 4 };
        return serve_sharded(
            cfg,
            exp.shards,
            &models,
            &source,
            &drive,
            exp.admin_socket.as_deref(),
            None,
            None,
        );
    }
    let row = latency::run_point(&cfg, &models, &source, exp.queries, rate, cfg.mode.name())?;
    println!("{}", parm::experiments::latency::LatencyRow::header());
    println!("{}", row.line());
    Ok(())
}

fn cmd_replay(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "parm replay",
        "re-execute a recorded serving-path journal and verify it: \
         parm replay <journal> (record one with `parm serve --record PATH`); \
         exits non-zero naming the first violated invariant and its event \
         index when verification fails",
    )
    .flag("report", "append the trace diagnostics (phase latency, group fates, fault windows)");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("parm replay needs a journal path"))?;
    let bytes = parm::coordinator::journal::read_file(path)?;
    let r = parm::coordinator::journal::replay(&bytes)
        .map_err(|e| anyhow::anyhow!("replay {path}: {e}"))?;
    println!(
        "replayed {path}: {} records, re-encode byte-identical (digest {:016x})",
        r.events, r.digest
    );
    println!("  run:     seed={} mode={}", r.seed, r.mode);
    println!(
        "  queries: submitted={} native={} reconstructed={} replica={} defaulted={} \
         rejected={} leaked={}",
        r.submits,
        r.totals.native,
        r.totals.reconstructed,
        r.totals.replica,
        r.totals.defaulted,
        r.totals.rejected,
        r.leaked,
    );
    println!(
        "  coding:  groups_sealed={} decodes={} reconstructions={}",
        r.seals, r.decodes, r.totals.reconstructions
    );
    println!("  chaos:   faults={} reconfigs={}", r.faults, r.reconfigs);
    println!("  wall:    {:.3}s", r.totals.wall_us as f64 / 1e6);
    if a.has_flag("report") {
        use parm::coordinator::trace::{analyze, report, AnalyzeOpts};
        let events = parm::coordinator::journal::decode(&bytes)?;
        let opts = AnalyzeOpts::default();
        println!("\n{}", report::render_text(&analyze(&events, &opts), &opts));
    }
    Ok(())
}

fn cmd_trace(argv: Vec<String>) -> anyhow::Result<()> {
    use parm::coordinator::trace::{analyze, chrome, report, AnalyzeOpts};
    let cli = Cli::new(
        "parm trace",
        "mine a recorded journal into diagnostics: per-query phase \
         breakdowns, group-fate timelines, fault-impact windows: \
         parm trace <journal> [--json] [--chrome OUT.json]",
    )
    .opt(
        "window-ms",
        "250",
        "fault-impact half-window W: distributions over [T-W,T), [T,T+W), [T+W,T+2W)",
    )
    .opt("slow", "5", "slowest-query exemplars to show in the text report")
    .opt(
        "chrome",
        "",
        "also write a Chrome/Perfetto trace-event export (open in \
         chrome://tracing or ui.perfetto.dev) to this path",
    )
    .flag("json", "machine-readable report on stdout instead of text");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("parm trace needs a journal path"))?;
    let window_ms = a.get_f64("window-ms");
    anyhow::ensure!(window_ms > 0.0, "--window-ms must be > 0");
    let opts = AnalyzeOpts {
        window_us: (window_ms * 1e3) as u64,
        slow: a.get_usize("slow"),
    };
    let bytes = parm::coordinator::journal::read_file(path)?;
    let events = parm::coordinator::journal::decode(&bytes)?;
    let analysis = analyze(&events, &opts);
    if a.has_flag("json") {
        println!("{}", report::render_json(&analysis));
    } else {
        print!("{}", report::render_text(&analysis, &opts));
    }
    match a.get("chrome") {
        "" => {}
        out => {
            std::fs::write(out, chrome::chrome_trace(&analysis))
                .map_err(|e| anyhow::anyhow!("write chrome trace {out}: {e}"))?;
            if !a.has_flag("json") {
                println!("chrome trace-event export at {out}");
            }
        }
    }
    Ok(())
}

fn cmd_mine(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = Cli::new(
        "parm mine",
        "reconstruct a replayable workload trace (arrival offsets + client \
         attribution) from a recorded journal: parm mine <journal> --out trace.json; \
         replay it with `parm serve --trace trace.json`",
    )
    .opt("out", "trace.json", "where to write the mined trace");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(parm::util::cli::CliError::Help) => {
            println!("{}", cli.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("parm mine needs a journal path"))?;
    let bytes = parm::coordinator::journal::read_file(path)?;
    let events = parm::coordinator::journal::decode(&bytes)?;
    let trace = parm::workload::trace::Trace::from_journal(&events)
        .map_err(|e| anyhow::anyhow!("mine {path}: {e}"))?;
    let out = a.get("out");
    trace.save(out).map_err(|e| anyhow::anyhow!("write trace {out}: {e}"))?;
    let (mean_gap, cv2) = trace.stats();
    println!(
        "mined {} arrivals from {path} to {out}: {:.1} qps nominal, mean gap {:.3}ms, \
         CV\u{b2} {cv2:.2}, burst ratio {:.2}, {} client(s)",
        trace.len(),
        trace.rate_qps,
        mean_gap * 1e3,
        trace.burst_ratio(20),
        trace.n_clients(),
    );
    Ok(())
}

fn cmd_table1() -> anyhow::Result<()> {
    println!("Table 1 toy example (X1=3, X2=4, P = X1+X2):");
    println!("{:<12} {:>10} {:>12} {:>18}", "F", "F(P)", "desired", "naive decode err");
    for r in table1::rows(3.0, 4.0) {
        println!(
            "{:<12} {:>10.2} {:>12.2} {:>18.2}",
            r.f_name, r.f_p, r.desired, r.naive_decode_err
        );
    }
    println!("\nnon-linear F breaks the plain addition code — the gap parity models close.");
    Ok(())
}

//! Time-series capture from the registry: the benches' and examples'
//! `bench_out/*_timeseries.json` rows, sampled from the *same* gauge
//! families an operator would scrape, instead of bespoke per-bench
//! sampling loops.
//!
//! A [`Capture`] is pointed at a window-gauge family prefix
//! (`parm_session_window_*` for a bare session,
//! `parm_fleet_window_*` for a control-plane fleet, or
//! `parm_shard_window_*` plus a `shard` label for one shard) and
//! sampled either on the caller's pacing loop ([`Capture::tick`]) or
//! at explicit instants ([`Capture::sample`] / [`Capture::mark`]).
//! Every sample runs the registry's samplers first, so pull-only state
//! (merged fleet windows, coding telemetry) is as fresh as a scrape
//! would see it.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::registry::Registry;
use crate::util::json::Json;

/// One periodic sample of a live window — the time-series view behind
/// "p99 over time across a fault event" plots (Figure 11's story told
/// as a timeline instead of end-of-run aggregates).
#[derive(Clone, Debug)]
pub struct TimeSeriesRow {
    /// Milliseconds since the run started.
    pub t_ms: f64,
    /// Queries resolved inside the window at this instant.
    pub resolved: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub qps: f64,
    pub recovery_rate: f64,
    pub reject_rate: f64,
    pub default_rate: f64,
}

impl TimeSeriesRow {
    pub fn from_snapshot(
        t: Duration,
        w: &crate::coordinator::metrics::WindowSnapshot,
    ) -> TimeSeriesRow {
        TimeSeriesRow {
            t_ms: t.as_secs_f64() * 1e3,
            resolved: w.resolved,
            p50_ms: w.p50_ms,
            p99_ms: w.p99_ms,
            p999_ms: w.p999_ms,
            qps: w.qps,
            recovery_rate: w.recovery_rate,
            reject_rate: w.reject_rate,
            default_rate: w.default_rate,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("t_ms", self.t_ms)
            .set("resolved", self.resolved as usize)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("p999_ms", self.p999_ms)
            .set("qps", self.qps)
            .set("recovery_rate", self.recovery_rate)
            .set("reject_rate", self.reject_rate)
            .set("default_rate", self.default_rate)
    }

    pub fn header() -> String {
        format!(
            "{:>9} {:>7} {:>9} {:>9} {:>9} {:>8} {:>9}",
            "t(ms)", "n", "p50(ms)", "p99(ms)", "p99.9(ms)", "qps", "recovery"
        )
    }

    pub fn line(&self) -> String {
        format!(
            "{:>9.0} {:>7} {:>9.3} {:>9.3} {:>9.3} {:>8.0} {:>9.3}",
            self.t_ms, self.resolved, self.p50_ms, self.p99_ms, self.p999_ms, self.qps,
            self.recovery_rate
        )
    }
}

/// Samples window-gauge families out of a [`Registry`] into
/// [`TimeSeriesRow`]-shaped JSON rows.
pub struct Capture {
    registry: Registry,
    /// Gauge family prefix, e.g. `parm_session_window_`.
    prefix: String,
    /// Label selector applied to every family read.
    labels: Vec<(String, String)>,
    /// Extra row columns: (row key, full family name, extra labels
    /// appended to the shared selector).
    extras: Vec<(String, String, Vec<(String, String)>)>,
    every: Duration,
    start: Instant,
    next: Instant,
    rows: Vec<Json>,
}

impl Capture {
    /// Capture a bare session's window (`parm_session_window_*`).
    pub fn session(registry: &Registry, every: Duration) -> Capture {
        Capture::new(registry, "parm_session_window_", every)
    }

    /// Capture a control-plane fleet's merged window
    /// (`parm_fleet_window_*`).
    pub fn fleet(registry: &Registry, every: Duration) -> Capture {
        Capture::new(registry, "parm_fleet_window_", every)
    }

    /// Capture an arbitrary window-gauge family prefix.
    pub fn new(registry: &Registry, prefix: &str, every: Duration) -> Capture {
        assert!(!every.is_zero(), "capture cadence must be non-zero");
        let now = Instant::now();
        Capture {
            registry: registry.clone(),
            prefix: prefix.to_string(),
            labels: Vec::new(),
            extras: Vec::new(),
            every,
            start: now,
            next: now + every,
            rows: Vec::new(),
        }
    }

    /// Restrict reads to series carrying this label (e.g.
    /// `("shard", "0")` against `parm_shard_window_*`).
    pub fn with_label(mut self, key: &str, value: impl std::fmt::Display) -> Capture {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }

    /// Add a column sampled from an arbitrary counter/gauge family
    /// (e.g. `("last_r", "parm_scheme_last_r")`), read with the same
    /// label selector as the window gauges.
    pub fn with_extra(mut self, row_key: &str, family: &str) -> Capture {
        self.extras.push((row_key.to_string(), family.to_string(), Vec::new()));
        self
    }

    /// Like [`Capture::with_extra`], but with additional labels on the
    /// read — how a fleet capture samples one series out of a labelled
    /// family (e.g. `("live", "parm_shards", &[("state", "live")])`).
    pub fn with_extra_labels(
        mut self,
        row_key: &str,
        family: &str,
        labels: &[(&str, &str)],
    ) -> Capture {
        let labels = labels.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect();
        self.extras.push((row_key.to_string(), family.to_string(), labels));
        self
    }

    /// Sample if the cadence is due (call from a pacing loop; cheap
    /// when not due). Returns whether a sample was taken. Lagged ticks
    /// skip forward instead of bursting.
    pub fn tick(&mut self) -> bool {
        let now = Instant::now();
        if now < self.next {
            return false;
        }
        self.sample_at(now);
        let mut next = self.next + self.every;
        while next <= now {
            next += self.every;
        }
        self.next = next;
        true
    }

    /// Take one sample now, regardless of cadence.
    pub fn sample(&mut self) {
        self.sample_at(Instant::now());
    }

    /// Take one sample now, annotated with an `event` field — how the
    /// elastic bench stamps reconfiguration verbs onto its timeline.
    pub fn mark(&mut self, event: &str) {
        let row = self.row(Instant::now()).set("event", event);
        self.rows.push(row);
    }

    fn sample_at(&mut self, now: Instant) {
        let row = self.row(now);
        self.rows.push(row);
    }

    fn read(&self, family: &str) -> f64 {
        self.read_with(family, &[])
    }

    fn read_with(&self, family: &str, extra: &[(String, String)]) -> f64 {
        let labels: Vec<(&str, &str)> = self
            .labels
            .iter()
            .chain(extra.iter())
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        self.registry.value(family, &labels).unwrap_or(0.0)
    }

    fn row(&self, now: Instant) -> Json {
        // Same freshness as a scrape: run the samplers first.
        self.registry.refresh();
        let g = |suffix: &str| self.read(&format!("{}{suffix}", self.prefix));
        let mut row = TimeSeriesRow {
            t_ms: now.saturating_duration_since(self.start).as_secs_f64() * 1e3,
            resolved: g("resolved") as u64,
            p50_ms: g("p50_ms"),
            p99_ms: g("p99_ms"),
            p999_ms: g("p999_ms"),
            qps: g("qps"),
            recovery_rate: g("recovery_rate"),
            reject_rate: g("reject_rate"),
            default_rate: g("default_rate"),
        }
        .to_json();
        for (key, family, extra) in &self.extras {
            row = row.set(key.as_str(), self.read_with(family, extra));
        }
        row
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn rows(&self) -> &[Json] {
        &self.rows
    }

    /// The captured rows as one JSON array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.rows.clone())
    }

    /// Print the table and write the rows to `bench_out/<name>.json`
    /// (the shape every `*_timeseries.json` consumer already reads).
    pub fn emit(&self, name: &str) -> Option<PathBuf> {
        println!("\n=== {name} ===");
        println!("{}", TimeSeriesRow::header());
        for row in &self.rows {
            let f = |k: &str| row.at(&[k]).as_f64().unwrap_or(0.0);
            let line = TimeSeriesRow {
                t_ms: f("t_ms"),
                resolved: f("resolved") as u64,
                p50_ms: f("p50_ms"),
                p99_ms: f("p99_ms"),
                p999_ms: f("p999_ms"),
                qps: f("qps"),
                recovery_rate: f("recovery_rate"),
                reject_rate: f("reject_rate"),
                default_rate: f("default_rate"),
            }
            .line();
            match row.at(&["event"]).as_str() {
                Some(ev) => println!("{line}  <- {ev}"),
                None => println!("{line}"),
            }
        }
        let _ = std::fs::create_dir_all("bench_out");
        let path = PathBuf::from(format!("bench_out/{name}.json"));
        match std::fs::write(&path, self.to_json().to_string()) {
            Ok(()) => {
                println!("(wrote {})", path.display());
                Some(path)
            }
            Err(e) => {
                log::warn!("telemetry: cannot write {}: {e}", path.display());
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::WindowSnapshot;

    fn publish(registry: &Registry, p50: f64, resolved: u64) {
        let snap = WindowSnapshot {
            p50_ms: p50,
            resolved,
            qps: 10.0,
            ..WindowSnapshot::zero(Duration::from_secs(1))
        };
        crate::telemetry::publish_window(registry, "parm_session_window_", &[], &snap);
    }

    #[test]
    fn capture_reads_window_gauges() {
        let registry = Registry::new();
        publish(&registry, 4.5, 12);
        let mut cap = Capture::session(&registry, Duration::from_millis(1));
        cap.sample();
        publish(&registry, 9.0, 20);
        cap.mark("kill");
        assert_eq!(cap.len(), 2);
        let rows = cap.rows();
        assert_eq!(rows[0].at(&["p50_ms"]).as_f64(), Some(4.5));
        assert_eq!(rows[0].at(&["resolved"]).as_f64(), Some(12.0));
        assert_eq!(rows[1].at(&["p50_ms"]).as_f64(), Some(9.0));
        assert_eq!(rows[1].at(&["event"]).as_str(), Some("kill"));
        assert!(rows[1].at(&["t_ms"]).as_f64().unwrap() >= rows[0].at(&["t_ms"]).as_f64().unwrap());
    }

    #[test]
    fn capture_tick_respects_cadence() {
        let registry = Registry::new();
        publish(&registry, 1.0, 1);
        let mut cap = Capture::session(&registry, Duration::from_secs(3600));
        assert!(!cap.tick(), "cadence not due yet");
        assert!(cap.is_empty());
    }

    #[test]
    fn capture_extras_and_labels() {
        let registry = Registry::new();
        let shard = registry.scoped("shard", 1);
        let snap = WindowSnapshot { p99_ms: 7.0, ..WindowSnapshot::zero(Duration::from_secs(1)) };
        crate::telemetry::publish_window(&shard, "parm_shard_window_", &[], &snap);
        shard.gauge("parm_scheme_last_r", "h", &[]).set(3.0);
        shard.gauge("parm_shards", "h", &[("state", "live")]).set(5.0);
        let mut cap = Capture::new(&registry, "parm_shard_window_", Duration::from_millis(1))
            .with_label("shard", 1)
            .with_extra("last_r", "parm_scheme_last_r")
            .with_extra_labels("live", "parm_shards", &[("state", "live")]);
        cap.sample();
        assert_eq!(cap.rows()[0].at(&["p99_ms"]).as_f64(), Some(7.0));
        assert_eq!(cap.rows()[0].at(&["last_r"]).as_f64(), Some(3.0));
        assert_eq!(cap.rows()[0].at(&["live"]).as_f64(), Some(5.0));
    }
}

//! Getting the registry out of the process: a pull-style Prometheus
//! text endpoint ([`Exporter`]) and a push-style JSON snapshot stream
//! ([`SnapshotLog`]).
//!
//! Both are strictly non-blocking for the serving path. The exporter
//! accepts on a dedicated thread and answers each scrape on its own
//! short-lived connection thread with read/write timeouts, so a
//! scraper that connects and then stalls mid-response wedges only its
//! own connection thread (until the write timeout fires), never an
//! accept, a render, or — above all — a submit. The snapshot log
//! samples on its own thread at a fixed interval; a full disk or a
//! dead file handle is logged and otherwise ignored.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::registry::Registry;
use crate::util::sync::{CondvarExt, LockExt};

/// Accept-loop poll cadence while idle (mirrors the admin socket's).
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Patience for a scraper's request head; scrapes are local, so this
/// is generous.
const READ_TIMEOUT: Duration = Duration::from_millis(500);
/// Per-`write` bound: a wedged scraper holds its connection thread at
/// most this long per buffered write before the thread gives up.
const WRITE_TIMEOUT: Duration = Duration::from_secs(2);

/// Prometheus text endpoint over a local TCP listener.
///
/// Serves `GET` anything (the path is not inspected — every request is
/// answered with the full registry rendering) with
/// `Content-Type: text/plain; version=0.0.4`, one response per
/// connection (`Connection: close`).
///
/// ```
/// use parm::telemetry::{Exporter, Registry};
///
/// let registry = Registry::new();
/// registry.counter("demo_total", "Demo.", &[]).inc();
/// let exporter = Exporter::bind("127.0.0.1:0", registry).unwrap();
/// // `curl http://{exporter.local_addr()}/metrics` would now answer.
/// assert_ne!(exporter.local_addr().port(), 0);
/// exporter.shutdown();
/// ```
pub struct Exporter {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl Exporter {
    /// Bind `addr` (e.g. `"127.0.0.1:9464"`; port 0 picks a free one)
    /// and start answering scrapes with `registry`'s rendering.
    pub fn bind(addr: &str, registry: Registry) -> anyhow::Result<Exporter> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("metrics: cannot bind {addr}: {e}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept = std::thread::Builder::new()
            .name("parm-metrics".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let reg = registry.clone();
                            // Detached: bounded by the read/write
                            // timeouts, not by our shutdown.
                            let _ = std::thread::Builder::new()
                                .name("parm-metrics-conn".into())
                                .spawn(move || serve_scrape(stream, &reg));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(ACCEPT_POLL);
                        }
                        Err(e) => {
                            log::warn!("metrics: accept failed: {e}");
                            std::thread::sleep(ACCEPT_POLL);
                        }
                    }
                }
            })
            .expect("spawn parm-metrics");
        Ok(Exporter { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. In-flight scrape
    /// connections finish (or time out) on their own.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Answer one scrape: swallow the request head, render, write, close.
/// Every error path is a plain return — a broken scraper costs us
/// nothing but this thread.
fn serve_scrape(mut stream: TcpStream, registry: &Registry) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    // Read until the blank line ending the request head (or until the
    // peer stalls/overflows — we answer anyway; scrapes are GETs).
    let mut head = [0u8; 4096];
    let mut n = 0;
    while n < head.len() {
        match stream.read(&mut head[n..]) {
            Ok(0) => break,
            Ok(k) => {
                n += k;
                if head[..n].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // Render *before* writing: all registry locks are released by the
    // time we block on the socket, so a wedged peer holds no lock.
    let body = registry.render();
    let header = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream
        .write_all(header.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .and_then(|_| stream.flush());
}

/// Push-style JSON snapshot stream: one
/// `{"t_ms": ..., "families": {...}}` line appended to a file per
/// interval, from the same registry the exporter serves
/// (`parm serve --metrics-log PATH`). A final sample is written at
/// shutdown so short runs always leave at least one.
pub struct SnapshotLog {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl SnapshotLog {
    /// Start sampling `registry` into `path` every `every`.
    pub fn start(
        path: impl AsRef<Path>,
        registry: Registry,
        every: Duration,
    ) -> anyhow::Result<SnapshotLog> {
        anyhow::ensure!(!every.is_zero(), "metrics-log interval must be non-zero");
        let path: PathBuf = path.as_ref().to_path_buf();
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("metrics: cannot open {}: {e}", path.display()))?;
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("parm-metrics-log".into())
            .spawn(move || {
                let started = Instant::now();
                let mut sample = |file: &mut std::fs::File| {
                    let line = crate::util::json::Json::obj()
                        .set("t_ms", started.elapsed().as_secs_f64() * 1000.0)
                        .set("families", registry.snapshot_json());
                    if let Err(e) = writeln!(file, "{line}") {
                        log::warn!("metrics: snapshot log write failed: {e}");
                    }
                };
                let (lock, cv) = &*stop2;
                let mut stopped = lock.plock();
                loop {
                    let (guard, timeout) = cv.pwait_timeout(stopped, every);
                    stopped = guard;
                    if *stopped {
                        break;
                    }
                    if timeout.timed_out() {
                        drop(stopped);
                        sample(&mut file);
                        stopped = lock.plock();
                    }
                }
                drop(stopped);
                sample(&mut file); // final sample at shutdown
            })
            .expect("spawn parm-metrics-log");
        Ok(SnapshotLog { stop, handle: Some(handle) })
    }

    /// Stop sampling (writes one final sample first).
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        *self.stop.0.plock() = true;
        self.stop.1.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SnapshotLog {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(addr: SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn exporter_serves_prometheus_text() {
        let registry = Registry::new();
        registry.counter("e2e_total", "h", &[]).add(7);
        let exporter = Exporter::bind("127.0.0.1:0", registry).unwrap();
        let reply = scrape(exporter.local_addr());
        assert!(reply.starts_with("HTTP/1.0 200 OK"), "got: {reply}");
        assert!(reply.contains("text/plain; version=0.0.4"));
        assert!(reply.contains("e2e_total 7"));
        exporter.shutdown();
    }

    #[test]
    fn exporter_answers_concurrent_scrapes() {
        let registry = Registry::new();
        registry.gauge("g", "h", &[]).set(1.0);
        let exporter = Exporter::bind("127.0.0.1:0", registry).unwrap();
        let addr = exporter.local_addr();
        let threads: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || scrape(addr)))
            .collect();
        for t in threads {
            assert!(t.join().unwrap().contains("g 1"));
        }
        exporter.shutdown();
    }

    #[test]
    fn snapshot_log_appends_samples() {
        let registry = Registry::new();
        registry.counter("s_total", "h", &[]).inc();
        let dir = std::env::temp_dir().join(format!("parm_snap_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("snap.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = SnapshotLog::start(&path, registry, Duration::from_millis(20)).unwrap();
        std::thread::sleep(Duration::from_millis(70));
        log.shutdown();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 2, "expected interval samples plus the final one: {text}");
        for line in lines {
            let j = crate::util::json::Json::parse(line).expect("valid JSON line");
            assert!(j.at(&["t_ms"]).as_f64().is_some());
            assert!(!matches!(
                j.at(&["families", "s_total"]),
                crate::util::json::Json::Null
            ));
        }
    }
}

//! First-class, strictly non-blocking observability for the serving
//! stack.
//!
//! Three layers, one pipe:
//!
//! - [`registry`] — a lock-light [`Registry`] of named metric families
//!   (counters, gauges, windowed-percentile summaries). Every tier
//!   publishes into it through cheap cloneable handles: sessions count
//!   submits/resolutions/rejects and outcome splits, schemes publish
//!   their operating point (last r, unavailability, parity overhead),
//!   the frontend publishes admission verdicts and per-client fairness
//!   weights, the sharded/cross-shard tiers publish per-shard windows
//!   and coding-group health, and the control plane publishes reconfig
//!   verbs and the fleet generation. Hot-path writes are wait-free
//!   atomic increments; registration (rare) takes a short write lock.
//! - [`export`] — an [`Exporter`] serving the registry as Prometheus
//!   text over a local TCP listener (`parm serve --metrics-addr`), and
//!   a push-style [`SnapshotLog`] appending one JSON sample per
//!   interval (`parm serve --metrics-log`). Both are strictly
//!   non-blocking for the serving path: a stalled or absent scraper
//!   can only ever stall its own connection thread, never a submit.
//! - [`series`] — a [`Capture`] layer that samples the registry into
//!   `bench_out/*_timeseries.json` rows, so bench time-series come from
//!   the same pipe an operator would scrape instead of bespoke
//!   per-bench sampling loops.
//!
//! The non-blocking contract, precisely: serving threads only ever
//! touch atomics (`Counter::inc`, `Gauge::set`, `Summary::observe`) or
//! a brief registration write lock at session/client setup; scrape-side
//! work (running samplers, sorting summary rings, rendering text,
//! socket writes) happens entirely on scraper/exporter threads.
//! Telemetry failure — unbindable port, wedged scraper, full disk on
//! the snapshot log — degrades observability, never serving.

pub mod export;
pub mod registry;
pub mod series;

pub use export::{Exporter, SnapshotLog};
pub use registry::{Counter, Gauge, Registry, Summary};
pub use series::Capture;

/// Gauge-family suffixes shared by every windowed-metrics publisher
/// (`parm_session_window_*`, `parm_fleet_window_*`,
/// `parm_shard_window_*`): one gauge per [`WindowSnapshot`] field.
///
/// [`WindowSnapshot`]: crate::coordinator::metrics::WindowSnapshot
pub const WINDOW_SUFFIXES: [&str; 10] = [
    "seconds",
    "resolved",
    "rejected",
    "p50_ms",
    "p99_ms",
    "p999_ms",
    "recovery_rate",
    "reject_rate",
    "default_rate",
    "qps",
];

/// Publish one [`WindowSnapshot`] as a gauge family under `prefix`
/// (e.g. `parm_session_window_`), with the given extra labels. The
/// shared helper behind every window publisher, so the exporter, the
/// admin socket, and the series layer all read identical families.
///
/// [`WindowSnapshot`]: crate::coordinator::metrics::WindowSnapshot
pub fn publish_window(
    registry: &Registry,
    prefix: &str,
    labels: &[(&str, &str)],
    snap: &crate::coordinator::metrics::WindowSnapshot,
) {
    let set = |suffix: &str, help: &str, v: f64| {
        registry.gauge(&format!("{prefix}{suffix}"), help, labels).set(v);
    };
    set("seconds", "Length of the sliding metrics window (s).", snap.window.as_secs_f64());
    set("resolved", "Queries resolved inside the window.", snap.resolved as f64);
    set("rejected", "Queries rejected by admission inside the window.", snap.rejected as f64);
    set("p50_ms", "Windowed median latency (ms).", snap.p50_ms);
    set("p99_ms", "Windowed p99 latency (ms).", snap.p99_ms);
    set("p999_ms", "Windowed p99.9 latency (ms).", snap.p999_ms);
    set("recovery_rate", "Fraction of resolved queries recovered by redundancy.", snap.recovery_rate);
    set("reject_rate", "rejected / (resolved + rejected) inside the window.", snap.reject_rate);
    set("default_rate", "Fraction of resolved queries that fell back to the SLO default.", snap.default_rate);
    set("qps", "Resolved-query throughput over the observed span.", snap.qps);
}

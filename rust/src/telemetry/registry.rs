//! The fleet-wide metric registry: named families of counters, gauges,
//! and windowed-percentile summaries, published into via cheap
//! cloneable handles and read out as Prometheus text or JSON.
//!
//! Design constraints (see the module docs in [`crate::telemetry`]):
//!
//! - **Wait-free hot path.** [`Counter::inc`], [`Gauge::set`], and
//!   [`Summary::observe`] are plain atomic operations on `Arc`-shared
//!   cells — no locks, no allocation, no syscalls. Serving threads
//!   never pay more than a few atomic stores per query.
//! - **Lock-light registration.** Creating or looking up a handle
//!   takes a short `RwLock` write; it happens at session/client setup
//!   and at scrape-refresh cadence, never per query. Registering the
//!   same (name, labels) twice returns a handle onto the *same* cell,
//!   so a re-provisioned shard continues its counters monotonically.
//! - **Scrape-side heavy lifting.** Sorting summary rings, running
//!   samplers, and rendering text all happen on the scraper's thread.
//!
//! A registry handle can be *scoped* ([`Registry::scoped`]): the clone
//! stamps extra base labels (e.g. `shard="3"`) onto every family
//! registered through it — how the sharded tier gives each shard
//! session its own label space on one shared registry.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::util::json::Json;
use crate::util::sync::{LockExt, RwLockExt};

/// Ring capacity of a [`Summary`]: percentiles are computed over the
/// most recent this-many observations (power of two; wrap is a mask).
const SUMMARY_CAPACITY: usize = 1024;

/// Metric family kinds, mirroring the Prometheus exposition types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Summary,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Summary => "summary",
        }
    }
}

/// A monotonically increasing counter. Cloning shares the cell.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    fn detached() -> Counter {
        Counter { cell: Arc::new(AtomicU64::new(0)) }
    }

    /// Wait-free increment (the hot-path operation).
    pub fn inc(&self) {
        self.add(1);
    }

    /// Wait-free add.
    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `total` if it is currently lower (no-op
    /// otherwise). For mirroring a cumulative total maintained
    /// elsewhere (e.g. a scheme's `groups_sealed`) while keeping the
    /// exported series monotonic even if publishers race.
    pub fn raise_to(&self, total: u64) {
        self.cell.fetch_max(total, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// An instantaneous value (f64). Cloning shares the cell.
#[derive(Clone)]
pub struct Gauge {
    bits: Arc<AtomicU64>,
}

impl Gauge {
    fn detached() -> Gauge {
        Gauge { bits: Arc::new(AtomicU64::new(0f64.to_bits())) }
    }

    /// Wait-free store. Non-finite values are recorded as 0 so the
    /// exported text never contains NaN/Inf.
    pub fn set(&self, v: f64) {
        let v = if v.is_finite() { v } else { 0.0 };
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

struct SummaryCore {
    /// f64 bit patterns of the most recent observations (lock-free
    /// ring; slots racing a wrap lose one sample, never block).
    ring: Box<[AtomicU64]>,
    /// Total observations ever; `head & (ring.len()-1)` is the slot.
    head: AtomicU64,
    /// Sum of observations in milli-units (value * 1000, truncated).
    sum_milli: AtomicU64,
}

/// A windowed-percentile summary over the most recent observations
/// (sample-windowed, not time-windowed: the last
/// [`SUMMARY_CAPACITY`] = 1024 samples). Cloning shares the ring.
#[derive(Clone)]
pub struct Summary {
    core: Arc<SummaryCore>,
}

impl Summary {
    fn detached() -> Summary {
        let ring = (0..SUMMARY_CAPACITY).map(|_| AtomicU64::new(0)).collect();
        Summary {
            core: Arc::new(SummaryCore {
                ring,
                head: AtomicU64::new(0),
                sum_milli: AtomicU64::new(0),
            }),
        }
    }

    /// Wait-free record: one fetch_add for the slot, one store for the
    /// sample, one fetch_add for the running sum.
    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let i = self.core.head.fetch_add(1, Ordering::Relaxed) as usize & (self.core.ring.len() - 1);
        self.core.ring[i].store(v.to_bits(), Ordering::Relaxed);
        self.core.sum_milli.fetch_add((v.max(0.0) * 1000.0) as u64, Ordering::Relaxed);
    }

    /// Observations ever recorded.
    pub fn count(&self) -> u64 {
        self.core.head.load(Ordering::Relaxed)
    }

    /// Sum of all observations ever recorded.
    pub fn sum(&self) -> f64 {
        self.core.sum_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Nearest-rank quantile over the retained samples; `0.0` with no
    /// samples (never NaN). Scrape-side only: copies and sorts up to
    /// [`SUMMARY_CAPACITY`] values.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = (self.count() as usize).min(self.core.ring.len());
        if n == 0 {
            return 0.0;
        }
        let mut vals: Vec<f64> = self.core.ring[..n]
            .iter()
            .map(|b| f64::from_bits(b.load(Ordering::Relaxed)))
            .filter(|v| v.is_finite())
            .collect();
        if vals.is_empty() {
            return 0.0;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
        let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
        vals[rank - 1]
    }
}

/// The quantiles a [`Summary`] exports, as (q, label) pairs.
pub const QUANTILES: [(f64, &str); 3] = [(0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")];

#[derive(Clone)]
enum Cell {
    Counter(Counter),
    Gauge(Gauge),
    Summary(Summary),
}

struct Family {
    kind: Kind,
    help: String,
    /// (sorted label pairs, cell) — a Vec scan suffices: family
    /// cardinality is shards × clients, registration is rare.
    series: Vec<(Vec<(String, String)>, Cell)>,
}

type Sampler = Box<dyn Fn() + Send + Sync>;

#[derive(Default)]
struct Inner {
    families: RwLock<BTreeMap<String, Family>>,
    /// Scrape-side refresh hooks (run by [`Registry::refresh`], i.e.
    /// on render/snapshot — never on the serving path). The mutex also
    /// serializes concurrent scrapers' refreshes.
    samplers: Mutex<Vec<(u64, Sampler)>>,
    next_sampler: AtomicU64,
}

/// Id returned by [`Registry::sampler`] for deregistration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplerId(u64);

/// A cheap-clone handle onto one shared metric store. See the module
/// docs for the design; in short: register handles once, increment
/// them wait-free forever, render from any thread.
///
/// ```
/// use parm::telemetry::Registry;
///
/// let registry = Registry::new();
/// let hits = registry.counter("demo_hits_total", "Requests served.", &[]);
/// hits.inc();
/// hits.add(2);
///
/// let text = registry.render();
/// assert!(text.contains("# TYPE demo_hits_total counter"));
/// assert!(text.contains("demo_hits_total 3"));
/// ```
///
/// Scoped handles stamp base labels onto everything registered through
/// them:
///
/// ```
/// use parm::telemetry::Registry;
///
/// let registry = Registry::new();
/// let shard3 = registry.scoped("shard", 3);
/// shard3.counter("demo_queries_total", "Queries.", &[]).inc();
/// assert!(registry.render().contains("demo_queries_total{shard=\"3\"} 1"));
/// ```
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
    /// Base labels stamped onto every registration through this handle.
    scope: Vec<(String, String)>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A clone that stamps `key="value"` onto every family registered
    /// through it (in addition to any labels passed at registration).
    /// The sharded tier hands each shard session a `scoped("shard", s)`
    /// clone of one fleet registry.
    pub fn scoped(&self, key: &str, value: impl std::fmt::Display) -> Registry {
        let mut scope = self.scope.clone();
        scope.push((key.to_string(), value.to_string()));
        Registry { inner: self.inner.clone(), scope }
    }

    fn canonical_labels(&self, labels: &[(&str, &str)]) -> Vec<(String, String)> {
        let mut all: Vec<(String, String)> = self
            .scope
            .iter()
            .cloned()
            .chain(labels.iter().map(|&(k, v)| (k.to_string(), v.to_string())))
            .collect();
        all.sort();
        all.dedup();
        all
    }

    /// Register-or-fetch one series cell. On a kind clash the handle is
    /// returned *detached* (live but unexported) — telemetry misuse must
    /// never panic a serving thread.
    fn cell(&self, name: &str, help: &str, kind: Kind, labels: &[(&str, &str)]) -> Cell {
        let labels = self.canonical_labels(labels);
        let mut families = self.inner.families.pwrite();
        let family = families.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help: help.to_string(),
            series: Vec::new(),
        });
        if family.kind != kind {
            log::error!(
                "telemetry: family {name} registered as {:?}, requested as {kind:?}; detaching",
                family.kind
            );
            return match kind {
                Kind::Counter => Cell::Counter(Counter::detached()),
                Kind::Gauge => Cell::Gauge(Gauge::detached()),
                Kind::Summary => Cell::Summary(Summary::detached()),
            };
        }
        if let Some((_, cell)) = family.series.iter().find(|(l, _)| *l == labels) {
            return cell.clone();
        }
        let cell = match kind {
            Kind::Counter => Cell::Counter(Counter::detached()),
            Kind::Gauge => Cell::Gauge(Gauge::detached()),
            Kind::Summary => Cell::Summary(Summary::detached()),
        };
        family.series.push((labels, cell.clone()));
        cell
    }

    /// Register (or fetch) a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.cell(name, help, Kind::Counter, labels) {
            Cell::Counter(c) => c,
            _ => Counter::detached(),
        }
    }

    /// Register (or fetch) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.cell(name, help, Kind::Gauge, labels) {
            Cell::Gauge(g) => g,
            _ => Gauge::detached(),
        }
    }

    /// Register (or fetch) a windowed-percentile summary series.
    pub fn summary(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Summary {
        match self.cell(name, help, Kind::Summary, labels) {
            Cell::Summary(s) => s,
            _ => Summary::detached(),
        }
    }

    /// Register a scrape-time refresh hook: `f` runs on the scraper's
    /// thread at every [`Registry::refresh`] (render/snapshot), typically
    /// to fold pull-only state (merged fleet windows, coding telemetry)
    /// into gauges. Samplers must not call back into
    /// render/snapshot/refresh.
    pub fn sampler(&self, f: impl Fn() + Send + Sync + 'static) -> SamplerId {
        let id = self.inner.next_sampler.fetch_add(1, Ordering::Relaxed);
        self.inner.samplers.plock().push((id, Box::new(f)));
        SamplerId(id)
    }

    /// Remove a sampler registered with [`Registry::sampler`].
    pub fn drop_sampler(&self, id: SamplerId) {
        self.inner.samplers.plock().retain(|(i, _)| *i != id.0);
    }

    /// Run every registered sampler (scrape-side; serialized across
    /// concurrent scrapers). Each sampler runs under `catch_unwind`: one
    /// panicking hook (a poisoned gauge source, a bug in a caller's
    /// closure) logs and skips instead of killing the scraper thread and
    /// poisoning the sampler list for every future scrape.
    pub fn refresh(&self) {
        let samplers = self.inner.samplers.plock();
        for (id, f) in samplers.iter() {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f())).is_err() {
                log::error!("telemetry sampler {id} panicked; metrics it feeds are stale");
            }
        }
    }

    /// Current value of one counter/gauge series (`None` if absent).
    /// Reads the live cell; does not run samplers — call
    /// [`Registry::refresh`] first if sampled families must be fresh.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let labels = self.canonical_labels(labels);
        let families = self.inner.families.pread();
        let family = families.get(name)?;
        let (_, cell) = family.series.iter().find(|(l, _)| *l == labels)?;
        match cell {
            Cell::Counter(c) => Some(c.get() as f64),
            Cell::Gauge(g) => Some(g.get()),
            Cell::Summary(_) => None,
        }
    }

    /// Every (labels, value) of one counter/gauge family (empty if the
    /// family is absent or a summary).
    pub fn series(&self, name: &str) -> Vec<(Vec<(String, String)>, f64)> {
        let families = self.inner.families.pread();
        let Some(family) = families.get(name) else { return Vec::new() };
        family
            .series
            .iter()
            .filter_map(|(labels, cell)| match cell {
                Cell::Counter(c) => Some((labels.clone(), c.get() as f64)),
                Cell::Gauge(g) => Some((labels.clone(), g.get())),
                Cell::Summary(_) => None,
            })
            .collect()
    }

    /// Render the registry as Prometheus text exposition format
    /// (version 0.0.4), running samplers first. Scrape-side only.
    pub fn render(&self) -> String {
        self.refresh();
        let mut out = String::new();
        let families = self.inner.families.pread();
        for (name, family) in families.iter() {
            let _ = writeln!(out, "# HELP {name} {}", escape_help(&family.help));
            let _ = writeln!(out, "# TYPE {name} {}", family.kind.as_str());
            for (labels, cell) in &family.series {
                match cell {
                    Cell::Counter(c) => {
                        let _ = writeln!(out, "{name}{} {}", fmt_labels(labels, None), c.get());
                    }
                    Cell::Gauge(g) => {
                        let _ =
                            writeln!(out, "{name}{} {}", fmt_labels(labels, None), fmt_f64(g.get()));
                    }
                    Cell::Summary(s) => {
                        for (q, ql) in QUANTILES {
                            let _ = writeln!(
                                out,
                                "{name}{} {}",
                                fmt_labels(labels, Some(ql)),
                                fmt_f64(s.quantile(q))
                            );
                        }
                        let _ = writeln!(
                            out,
                            "{name}_sum{} {}",
                            fmt_labels(labels, None),
                            fmt_f64(s.sum())
                        );
                        let _ =
                            writeln!(out, "{name}_count{} {}", fmt_labels(labels, None), s.count());
                    }
                }
            }
        }
        out
    }

    /// The registry as one JSON object (the [`SnapshotLog`] sample and
    /// the raw material of [`crate::telemetry::series`]), running
    /// samplers first. Families map name → array of
    /// `{labels, value}` (counters/gauges) or
    /// `{labels, count, sum, p50, p99, p999}` (summaries).
    ///
    /// [`SnapshotLog`]: crate::telemetry::export::SnapshotLog
    pub fn snapshot_json(&self) -> Json {
        self.refresh();
        let families = self.inner.families.pread();
        let mut out = Json::obj();
        for (name, family) in families.iter() {
            let rows: Vec<Json> = family
                .series
                .iter()
                .map(|(labels, cell)| {
                    let mut lab = Json::obj();
                    for (k, v) in labels {
                        lab = lab.set(k.as_str(), v.as_str());
                    }
                    let row = Json::obj().set("labels", lab);
                    match cell {
                        Cell::Counter(c) => row.set("value", c.get()),
                        Cell::Gauge(g) => row.set("value", g.get()),
                        Cell::Summary(s) => row
                            .set("count", s.count())
                            .set("sum", s.sum())
                            .set("p50", s.quantile(0.5))
                            .set("p99", s.quantile(0.99))
                            .set("p999", s.quantile(0.999)),
                    }
                })
                .collect();
            out = out.set(name.as_str(), Json::Arr(rows));
        }
        out
    }
}

fn fmt_labels(labels: &[(String, String)], quantile: Option<&str>) -> String {
    if labels.is_empty() && quantile.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some(q) = quantile {
        parts.push(format!("quantile=\"{q}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Prometheus-safe float formatting: no NaN/Inf, integral values
/// without a fraction.
fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_shared_and_monotonic() {
        let r = Registry::new();
        let a = r.counter("t_total", "h", &[]);
        let b = r.counter("t_total", "h", &[]);
        a.inc();
        b.add(4);
        a.raise_to(3); // below current 5: no-op
        assert_eq!(a.get(), 5);
        b.raise_to(9);
        assert_eq!(a.get(), 9);
    }

    #[test]
    fn scoped_labels_stamp_and_sort() {
        let r = Registry::new();
        let s = r.scoped("shard", 2);
        s.gauge("g", "h", &[("client", "7")]).set(1.5);
        let series = r.series("g");
        assert_eq!(series.len(), 1);
        assert_eq!(
            series[0].0,
            vec![("client".to_string(), "7".to_string()), ("shard".to_string(), "2".to_string())]
        );
        assert_eq!(series[0].1, 1.5);
        assert_eq!(r.value("g", &[("shard", "2"), ("client", "7")]), Some(1.5));
    }

    #[test]
    fn kind_clash_detaches_instead_of_panicking() {
        let r = Registry::new();
        r.counter("x", "h", &[]).inc();
        let g = r.gauge("x", "h", &[]);
        g.set(7.0); // lands in a detached cell
        assert_eq!(r.value("x", &[]), Some(1.0));
        assert!(r.render().contains("x 1"));
    }

    #[test]
    fn summary_quantiles_and_render() {
        let r = Registry::new();
        let s = r.summary("lat_ms", "h", &[]);
        assert_eq!(s.quantile(0.99), 0.0, "empty summary reads zero, not NaN");
        for i in 1..=100 {
            s.observe(i as f64);
        }
        assert_eq!(s.quantile(0.5), 50.0);
        assert_eq!(s.quantile(0.99), 99.0);
        assert_eq!(s.count(), 100);
        let text = r.render();
        assert!(text.contains("lat_ms{quantile=\"0.5\"} 50"));
        assert!(text.contains("lat_ms_count 100"));
    }

    #[test]
    fn summary_ring_wraps_to_recent_samples() {
        let r = Registry::new();
        let s = r.summary("w", "h", &[]);
        for _ in 0..SUMMARY_CAPACITY {
            s.observe(1.0);
        }
        for _ in 0..SUMMARY_CAPACITY {
            s.observe(100.0);
        }
        assert_eq!(s.quantile(0.5), 100.0, "old samples aged out");
    }

    #[test]
    fn gauges_never_export_nan() {
        let r = Registry::new();
        let g = r.gauge("n", "h", &[]);
        g.set(f64::NAN);
        assert_eq!(g.get(), 0.0);
        g.set(f64::INFINITY);
        assert_eq!(g.get(), 0.0);
    }

    #[test]
    fn samplers_run_on_refresh_and_drop() {
        let r = Registry::new();
        let g = r.gauge("sampled", "h", &[]);
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        let g2 = g.clone();
        let id = r.sampler(move || {
            h2.fetch_add(1, Ordering::Relaxed);
            g2.set(42.0);
        });
        let text = r.render();
        assert!(text.contains("sampled 42"));
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        r.drop_sampler(id);
        r.refresh();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn label_values_are_escaped() {
        let r = Registry::new();
        r.gauge("e", "h", &[("k", "a\"b\\c")]).set(1.0);
        assert!(r.render().contains("e{k=\"a\\\"b\\\\c\"} 1"));
    }
}

//! Artifact inventory: the manifest written by `python/compile/aot.py`
//! (`make artifacts`) describing every AOT-lowered model, plus the dumped
//! test splits the Rust side serves as queries.
//!
//! Two sources:
//!
//! - **On-disk**: `<dir>/manifest.json` in the `hlo-text-v1` format of
//!   `aot.py`, with `<name>.b<batch>.hlo.txt` programs and
//!   `<dataset>.test_{x,y}.bin` raw little-endian splits next to it.
//! - **Synthetic fallback**: when no artifacts directory exists,
//!   [`Manifest::load_default`] fabricates a deterministic inventory that
//!   mirrors `aot.py`'s build matrix (same names, roles, k/r/encoder
//!   combinations) with small input shapes and seeded pseudo test sets.
//!   Paired with the synthetic execution backend (see
//!   [`crate::runtime::engine`]) this keeps every serving-path test and
//!   bench runnable on hosts that never ran `make artifacts`. Trained
//!   accuracy semantics are absent, so accuracy-asserting tests must skip
//!   when [`Manifest::synthetic`] is in effect.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::{fnv1a, Pcg64};

#[derive(Debug, thiserror::Error)]
pub enum ArtifactError {
    #[error("artifact io {path}: {err}")]
    Io { path: String, err: std::io::Error },
    #[error("manifest parse: {0}")]
    Parse(#[from] crate::util::json::ParseError),
    #[error("manifest invalid: {0}")]
    Invalid(String),
    #[error("no model {0:?} in manifest")]
    NoModel(String),
    #[error("no dataset {0:?} in manifest")]
    NoDataset(String),
    #[error("model {model:?} has no batch-{batch} artifact (have {have:?})")]
    NoBatch { model: String, batch: usize, have: Vec<usize> },
}

/// One AOT-exported model variant.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    /// "deployed" | "parity" | "approx" | "encoder".
    pub role: String,
    pub dataset: String,
    pub arch: String,
    /// Per-sample input shape (no batch dim).
    pub input_shape: Vec<usize>,
    /// Output vector length per sample.
    pub out_dim: usize,
    /// Coding-group size (parity models; 0 otherwise).
    pub k: usize,
    /// Which parity of an r > 1 code this model is (§3.5).
    pub r_index: usize,
    /// Encoder the parity model was trained against ("" for deployed).
    pub encoder: String,
    /// Eval metric stamped at train time (accuracy / A_d / IoU).
    pub train_metric: f64,
    /// batch size -> HLO file name.
    pub files: BTreeMap<usize, String>,
}

/// One dumped dataset test split.
#[derive(Clone, Debug)]
pub struct DatasetEntry {
    pub name: String,
    /// "classify" | "localize".
    pub task: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub n_test: usize,
    /// Raw little-endian f32 sample file.
    pub test_x: String,
    /// Raw label file (i32 classes or f32 boxes).
    pub test_y: String,
}

/// Test-split labels.
pub enum Labels {
    Classes(Vec<i32>),
    /// (cx, cy, w, h) in normalized coordinates.
    Boxes(Vec<[f32; 4]>),
}

/// The artifact inventory.
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
    pub datasets: Vec<DatasetEntry>,
    pub fast_mode: bool,
    /// True when this inventory was fabricated (no artifacts on disk).
    pub synthetic: bool,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|err| ArtifactError::Io { path: path.display().to_string(), err })?;
        let j = Json::parse(&text)?;

        let mut models = Vec::new();
        for m in j.at(&["models"]).as_arr().unwrap_or(&[]) {
            models.push(parse_model(m)?);
        }
        let mut datasets = Vec::new();
        for d in j.at(&["datasets"]).as_arr().unwrap_or(&[]) {
            datasets.push(parse_dataset(d)?);
        }
        if models.is_empty() {
            return Err(ArtifactError::Invalid("manifest lists no models".into()));
        }
        Ok(Manifest {
            dir,
            models,
            datasets,
            fast_mode: j.at(&["fast_mode"]).as_bool().unwrap_or(false),
            synthetic: false,
        })
    }

    /// Load the default artifacts: `$PARM_ARTIFACTS`, then `./artifacts`,
    /// then `../artifacts` (package dir vs repo root), falling back to the
    /// deterministic synthetic inventory when none exists.
    pub fn load_default() -> Result<Manifest, ArtifactError> {
        let mut candidates: Vec<PathBuf> = Vec::new();
        if let Ok(dir) = std::env::var("PARM_ARTIFACTS") {
            candidates.push(PathBuf::from(dir));
        }
        candidates.push(PathBuf::from("artifacts"));
        candidates.push(PathBuf::from("../artifacts"));
        for dir in candidates {
            if dir.join("manifest.json").exists() {
                return Manifest::load(dir);
            }
        }
        log::warn!(
            "no AOT artifacts found (run `make artifacts`); using the synthetic inventory"
        );
        Ok(Manifest::synthetic())
    }

    /// The fabricated inventory mirroring `aot.py`'s build matrix.
    pub fn synthetic() -> Manifest {
        synthetic_manifest()
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry, ArtifactError> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| ArtifactError::NoModel(name.to_string()))
    }

    /// The deployed model for (dataset, arch).
    pub fn deployed(&self, dataset: &str, arch: &str) -> Result<&ModelEntry, ArtifactError> {
        self.models
            .iter()
            .find(|m| m.role == "deployed" && m.dataset == dataset && m.arch == arch)
            .ok_or_else(|| ArtifactError::NoModel(format!("{dataset}.{arch}.deployed")))
    }

    /// The parity model for (dataset, arch, k, encoder, r_index).
    pub fn parity(
        &self,
        dataset: &str,
        arch: &str,
        k: usize,
        encoder: &str,
        r_index: usize,
    ) -> Result<&ModelEntry, ArtifactError> {
        self.models
            .iter()
            .find(|m| {
                m.role == "parity"
                    && m.dataset == dataset
                    && m.arch == arch
                    && m.k == k
                    && m.encoder == encoder
                    && m.r_index == r_index
            })
            .ok_or_else(|| {
                ArtifactError::NoModel(format!(
                    "{dataset}.{arch}.parity.k{k}.{encoder} (r_index {r_index})"
                ))
            })
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetEntry, ArtifactError> {
        self.datasets
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| ArtifactError::NoDataset(name.to_string()))
    }

    /// Path of `entry`'s HLO program for `batch`.
    pub fn hlo_path(&self, entry: &ModelEntry, batch: usize) -> Result<PathBuf, ArtifactError> {
        let f = entry.files.get(&batch).ok_or_else(|| ArtifactError::NoBatch {
            model: entry.name.clone(),
            batch,
            have: entry.files.keys().copied().collect(),
        })?;
        Ok(self.dir.join(f))
    }

    /// Load a dataset's test split: per-sample query tensors plus labels.
    pub fn load_test_set(&self, ds: &DatasetEntry) -> Result<(Vec<Tensor>, Labels), ArtifactError> {
        if self.synthetic {
            return Ok(synthetic_test_set(ds));
        }
        let per: usize = ds.input_shape.iter().product();
        let xs = read_f32(&self.dir.join(&ds.test_x))?;
        let n = ds.n_test.min(xs.len() / per.max(1));
        let queries: Vec<Tensor> = (0..n)
            .map(|i| {
                Tensor::new(ds.input_shape.clone(), xs[i * per..(i + 1) * per].to_vec())
                    .expect("shape matches stride")
            })
            .collect();
        let ypath = self.dir.join(&ds.test_y);
        let labels = if ds.task == "classify" {
            Labels::Classes(read_i32(&ypath)?.into_iter().take(n).collect())
        } else {
            let raw = read_f32(&ypath)?;
            Labels::Boxes(
                raw.chunks_exact(4)
                    .take(n)
                    .map(|c| [c[0], c[1], c[2], c[3]])
                    .collect(),
            )
        };
        Ok((queries, labels))
    }
}

fn parse_model(j: &Json) -> Result<ModelEntry, ArtifactError> {
    let name = j
        .at(&["name"])
        .as_str()
        .ok_or_else(|| ArtifactError::Invalid("model entry missing name".into()))?
        .to_string();
    let mut files = BTreeMap::new();
    if let Some(obj) = j.at(&["files"]).as_obj() {
        for (batch, fname) in obj {
            let b: usize = batch
                .parse()
                .map_err(|_| ArtifactError::Invalid(format!("{name}: bad batch key {batch:?}")))?;
            let f = fname
                .as_str()
                .ok_or_else(|| ArtifactError::Invalid(format!("{name}: non-string file")))?;
            files.insert(b, f.to_string());
        }
    }
    if files.is_empty() {
        return Err(ArtifactError::Invalid(format!("{name}: no artifact files")));
    }
    let input_shape = parse_shape(j.at(&["input_shape"]), &name)?;
    Ok(ModelEntry {
        role: j.at(&["role"]).as_str().unwrap_or("deployed").to_string(),
        dataset: j.at(&["dataset"]).as_str().unwrap_or("").to_string(),
        arch: j.at(&["arch"]).as_str().unwrap_or("").to_string(),
        input_shape,
        out_dim: j.at(&["out_dim"]).as_usize().unwrap_or(0),
        k: j.at(&["k"]).as_usize().unwrap_or(0),
        r_index: j.at(&["r_index"]).as_usize().unwrap_or(0),
        encoder: j.at(&["encoder"]).as_str().unwrap_or("").to_string(),
        train_metric: j.at(&["train_metric"]).as_f64().unwrap_or(f64::NAN),
        files,
        name,
    })
}

fn parse_dataset(j: &Json) -> Result<DatasetEntry, ArtifactError> {
    let name = j
        .at(&["name"])
        .as_str()
        .ok_or_else(|| ArtifactError::Invalid("dataset entry missing name".into()))?
        .to_string();
    let input_shape = parse_shape(j.at(&["input_shape"]), &name)?;
    Ok(DatasetEntry {
        task: j.at(&["task"]).as_str().unwrap_or("classify").to_string(),
        num_classes: j.at(&["num_classes"]).as_usize().unwrap_or(0),
        input_shape,
        n_test: j.at(&["n_test"]).as_usize().unwrap_or(0),
        test_x: match j.at(&["test_x"]).as_str() {
            Some(s) => s.to_string(),
            None => format!("{name}.test_x.bin"),
        },
        test_y: match j.at(&["test_y"]).as_str() {
            Some(s) => s.to_string(),
            None => format!("{name}.test_y.bin"),
        },
        name,
    })
}

fn parse_shape(j: &Json, name: &str) -> Result<Vec<usize>, ArtifactError> {
    let arr = j
        .as_arr()
        .ok_or_else(|| ArtifactError::Invalid(format!("{name}: missing input_shape")))?;
    arr.iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| ArtifactError::Invalid(format!("{name}: bad shape dim")))
        })
        .collect()
}

fn read_f32(path: &Path) -> Result<Vec<f32>, ArtifactError> {
    let bytes = std::fs::read(path)
        .map_err(|err| ArtifactError::Io { path: path.display().to_string(), err })?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32(path: &Path) -> Result<Vec<i32>, ArtifactError> {
    let bytes = std::fs::read(path)
        .map_err(|err| ArtifactError::Io { path: path.display().to_string(), err })?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ------------------------------------------------------------------------
// Synthetic inventory
// ------------------------------------------------------------------------

/// Samples per synthetic test split: divisible by every supported k.
const SYNTH_N_TEST: usize = 240;

struct SynthBuilder {
    models: Vec<ModelEntry>,
    datasets: Vec<DatasetEntry>,
}

impl SynthBuilder {
    fn dataset(&mut self, name: &str, task: &str, num_classes: usize, shape: &[usize]) {
        self.datasets.push(DatasetEntry {
            name: name.to_string(),
            task: task.to_string(),
            num_classes,
            input_shape: shape.to_vec(),
            n_test: SYNTH_N_TEST,
            test_x: format!("{name}.test_x.bin"),
            test_y: format!("{name}.test_y.bin"),
        });
    }

    #[allow(clippy::too_many_arguments)]
    fn model(
        &mut self,
        name: String,
        role: &str,
        dataset: &str,
        arch: &str,
        input_shape: Vec<usize>,
        out_dim: usize,
        batches: &[usize],
        k: usize,
        r_index: usize,
        encoder: &str,
    ) {
        let files = batches
            .iter()
            .map(|&b| (b, format!("{name}.b{b}.hlo.txt")))
            .collect();
        // Deterministic plausible metric per entry (not trained semantics).
        let h = fnv1a(name.as_bytes());
        let train_metric = match role {
            "deployed" => 0.85 + (h % 100) as f64 / 1000.0,
            "parity" => 0.55 + (h % 200) as f64 / 1000.0,
            "approx" => 0.70 + (h % 100) as f64 / 1000.0,
            _ => f64::NAN,
        };
        self.models.push(ModelEntry {
            name,
            role: role.to_string(),
            dataset: dataset.to_string(),
            arch: arch.to_string(),
            input_shape,
            out_dim,
            k,
            r_index,
            encoder: encoder.to_string(),
            train_metric,
            files,
        });
    }
}

/// Mirror `aot.py`'s ACCURACY_MATRIX + LATENCY build matrix with small
/// shapes so everything the benches and tests look up by name exists.
fn synthetic_manifest() -> Manifest {
    let mut b = SynthBuilder { models: Vec::new(), datasets: Vec::new() };

    b.dataset("synthvision10", "classify", 10, &[16, 16, 3]);
    b.dataset("synthvision100", "classify", 100, &[16, 16, 3]);
    b.dataset("synthfashion", "classify", 10, &[16, 16, 1]);
    b.dataset("synthdigits", "classify", 10, &[16, 16, 1]);
    b.dataset("synthspeech", "classify", 10, &[16, 16, 1]);
    b.dataset("synthloc", "localize", 0, &[16, 16, 3]);
    b.dataset("synthpets", "classify", 2, &[16, 16, 3]);

    // (dataset, arch, sum ks, concat ks, second r=2 parity)
    let matrix: &[(&str, &str, &[usize], &[usize], bool)] = &[
        ("synthvision10", "microresnet", &[2, 3, 4], &[2, 4], true),
        ("synthvision100", "microresnet", &[2], &[], false),
        ("synthfashion", "mlp", &[2], &[], false),
        ("synthfashion", "lenet", &[2], &[], false),
        ("synthfashion", "microresnet", &[2, 3, 4], &[], false),
        ("synthdigits", "lenet", &[2, 3, 4], &[], false),
        ("synthspeech", "lenet", &[2, 3, 4], &[], false),
        ("synthloc", "microresnet", &[2], &[], false),
    ];
    for &(ds_name, arch, sum_ks, concat_ks, r2) in matrix {
        let ds = b.datasets.iter().find(|d| d.name == ds_name).unwrap().clone();
        let out_dim = if ds.task == "classify" { ds.num_classes } else { 4 };
        let tag = format!("{ds_name}.{arch}");
        b.model(
            format!("{tag}.deployed"),
            "deployed",
            ds_name,
            arch,
            ds.input_shape.clone(),
            out_dim,
            &[1, 50],
            0,
            0,
            "",
        );
        for (enc, ks) in [("sum", sum_ks), ("concat", concat_ks)] {
            for &k in ks {
                b.model(
                    format!("{tag}.parity.k{k}.{enc}"),
                    "parity",
                    ds_name,
                    arch,
                    ds.input_shape.clone(),
                    out_dim,
                    &[1, 50],
                    k,
                    0,
                    enc,
                );
            }
        }
        if r2 {
            b.model(
                format!("{tag}.parity.k2.sum.r1"),
                "parity",
                ds_name,
                arch,
                ds.input_shape.clone(),
                out_dim,
                &[1, 50],
                2,
                1,
                "sum",
            );
        }
    }

    // Latency workload (§5.1): 1000-float predictions, batches 1/2/4.
    let pets_shape = vec![16usize, 16, 3];
    let tag = "synthpets.microresnet";
    b.model(
        format!("{tag}.deployed1000"),
        "deployed",
        "synthpets",
        "microresnet",
        pets_shape.clone(),
        1000,
        &[1, 2, 4],
        0,
        0,
        "",
    );
    for k in [2usize, 3, 4] {
        b.model(
            format!("{tag}.parity1000.k{k}.sum"),
            "parity",
            "synthpets",
            "microresnet",
            pets_shape.clone(),
            1000,
            &[1, 2, 4],
            k,
            0,
            "sum",
        );
    }
    b.model(
        format!("{tag}.approx1000"),
        "approx",
        "synthpets",
        "microresnet_narrow",
        pets_shape.clone(),
        1000,
        &[1, 2, 4],
        0,
        0,
        "",
    );
    let pets_elems: usize = pets_shape.iter().product();
    for k in [2usize, 3, 4] {
        let mut shape = vec![k];
        shape.extend_from_slice(&pets_shape);
        b.model(
            format!("encoder.sum.k{k}"),
            "encoder",
            "synthpets",
            "pallas-sum",
            shape,
            pets_elems,
            &[1],
            k,
            0,
            "sum",
        );
    }

    Manifest {
        dir: PathBuf::from("<synthetic>"),
        models: b.models,
        datasets: b.datasets,
        fast_mode: true,
        synthetic: true,
    }
}

/// Seeded pseudo test split: queries in [0, 1), labels uniform.
fn synthetic_test_set(ds: &DatasetEntry) -> (Vec<Tensor>, Labels) {
    let mut rng = Pcg64::new(fnv1a(ds.name.as_bytes()));
    let per: usize = ds.input_shape.iter().product();
    let queries: Vec<Tensor> = (0..ds.n_test)
        .map(|_| {
            Tensor::new(ds.input_shape.clone(), (0..per).map(|_| rng.next_f32()).collect())
                .expect("shape matches data")
        })
        .collect();
    let labels = if ds.task == "classify" {
        Labels::Classes(
            (0..ds.n_test)
                .map(|_| rng.below(ds.num_classes.max(1) as u64) as i32)
                .collect(),
        )
    } else {
        Labels::Boxes(
            (0..ds.n_test)
                .map(|_| {
                    [
                        rng.range_f64(0.2, 0.8) as f32,
                        rng.range_f64(0.2, 0.8) as f32,
                        rng.range_f64(0.1, 0.5) as f32,
                        rng.range_f64(0.1, 0.5) as f32,
                    ]
                })
                .collect(),
        )
    };
    (queries, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_mirrors_build_matrix() {
        let m = Manifest::synthetic();
        assert!(m.synthetic);
        // Name-based lookups used across the benches and experiments.
        assert!(m.model("synthpets.microresnet.deployed1000").is_ok());
        assert!(m.model("synthpets.microresnet.parity1000.k2.sum").is_ok());
        assert!(m.model("synthpets.microresnet.approx1000").is_ok());
        assert!(m.model("encoder.sum.k3").is_ok());
        assert!(m.deployed("synthdigits", "lenet").is_ok());
        assert!(m.parity("synthvision10", "microresnet", 2, "sum", 1).is_ok());
        assert!(m.parity("synthvision10", "microresnet", 4, "concat", 0).is_ok());
        assert!(m.dataset("synthloc").is_ok());
        assert!(m.model("no.such.model").is_err());
    }

    #[test]
    fn synthetic_test_set_is_deterministic_and_shaped() {
        let m = Manifest::synthetic();
        let ds = m.dataset("synthpets").unwrap();
        let (q1, l1) = m.load_test_set(ds).unwrap();
        let (q2, _) = m.load_test_set(ds).unwrap();
        assert_eq!(q1.len(), SYNTH_N_TEST);
        assert_eq!(q1[0].shape(), &[16, 16, 3]);
        assert_eq!(q1[0], q2[0], "seeded by dataset name");
        match l1 {
            Labels::Classes(c) => {
                assert_eq!(c.len(), SYNTH_N_TEST);
                assert!(c.iter().all(|&l| (0..2).contains(&l)));
            }
            _ => panic!("synthpets is a classification dataset"),
        }
    }

    #[test]
    fn localization_labels_are_boxes() {
        let m = Manifest::synthetic();
        let ds = m.dataset("synthloc").unwrap();
        let (_, labels) = m.load_test_set(ds).unwrap();
        match labels {
            Labels::Boxes(b) => {
                assert_eq!(b.len(), SYNTH_N_TEST);
                assert!(b.iter().all(|x| x.iter().all(|v| (0.0..=1.0).contains(v))));
            }
            _ => panic!("synthloc is a localization dataset"),
        }
    }

    #[test]
    fn hlo_path_reports_missing_batches() {
        let m = Manifest::synthetic();
        let e = m.model("synthpets.microresnet.deployed1000").unwrap();
        assert!(m.hlo_path(e, 2).is_ok());
        match m.hlo_path(e, 7) {
            Err(ArtifactError::NoBatch { batch: 7, .. }) => {}
            other => panic!("expected NoBatch, got {other:?}"),
        }
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(Manifest::load("/no/such/artifact/dir").is_err());
    }
}

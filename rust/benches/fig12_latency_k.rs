//! Figure 12: latency of ParM at k = 2, 3, 4 (33%/25%/20% redundancy) at a
//! fixed query rate on the GPU-profile cluster, vs Equal-Resources with
//! 33% redundancy — the paper's redundancy/latency trade-off.

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::experiments::latency;
use parm::workload::QuerySource;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;

    let mut rows = Vec::new();
    // Fixed operating point ~ the paper's 270 qps on the GPU cluster.
    let util = 0.55;
    for k in [2usize, 3, 4] {
        let models = latency::load_models(&m, 1, k, 1, false)?;
        let mean = parm::coordinator::service::measure_service(
            &models.deployed,
            &parm::tensor::Tensor::batch(&[source.queries[0].clone()])?,
            20,
        );
        let capacity = GPU.default_m as f64 / mean.as_secs_f64();
        let rate = util * capacity;
        let mut cfg = ServiceConfig::defaults(
            Mode::Parm { k, encoders: vec![Encoder::sum(k)] },
            &GPU,
        );
        cfg.seed = 0xF16_12 + k as u64;
        rows.push(latency::run_point(
            &cfg,
            &models,
            &source,
            n,
            rate,
            &format!("parm[k={k},{}% red.]", 100 / k),
        )?);
        if k == 2 {
            let mut cfg = ServiceConfig::defaults(Mode::EqualResources { k }, &GPU);
            cfg.seed = 0xF16_12;
            rows.push(latency::run_point(
                &cfg, &models, &source, n, rate, "equal-resources[33% red.]",
            )?);
        }
    }
    latency::emit("fig12_latency_k", &rows);
    Ok(())
}

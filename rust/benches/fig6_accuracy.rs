//! Figure 6: degraded-mode accuracy (A_d) of ParM with k=2 and the generic
//! sum encoder, per task, vs the deployed model (A_a) and the Clipper
//! default-prediction baseline. Regenerates the paper's bar chart as rows.

use parm::artifacts::Manifest;
use parm::experiments::accuracy;
use parm::util::json::Json;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;

    println!("=== Figure 6: A_a vs ParM A_d vs default (k=2, sum encoder) ===");
    println!(
        "{:<16} {:<13} {:<8} {:>8} {:>8} {:>9} {:>10}",
        "dataset", "arch", "metric", "A_a", "A_d", "default", "stripes"
    );
    let mut out = Vec::new();
    for model in m.models.iter().filter(|x| x.role == "parity") {
        if model.k != 2 || model.encoder != "sum" || model.r_index != 0 {
            continue;
        }
        if model.name.contains("1000") {
            continue; // latency-workload variant; fig6 uses task models
        }
        let dep = m.deployed(&model.dataset, &model.arch)?;
        let r = accuracy::evaluate(&m, dep, model, 7)?;
        println!(
            "{:<16} {:<13} {:<8} {:>8.3} {:>8.3} {:>9.3} {:>10}",
            r.dataset, r.arch, r.metric, r.available, r.degraded,
            r.default_baseline, r.n_stripes
        );
        out.push(
            Json::obj()
                .set("dataset", r.dataset.as_str())
                .set("arch", r.arch.as_str())
                .set("metric", r.metric)
                .set("available", r.available)
                .set("degraded", r.degraded)
                .set("default", r.default_baseline),
        );
    }
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/fig6_accuracy.json", Json::Arr(out).to_string())?;
    println!("(wrote bench_out/fig6_accuracy.json)");
    Ok(())
}

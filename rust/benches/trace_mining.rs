//! Journal-mining throughput: how fast does the diagnostics layer
//! (`coordinator::trace`) chew through a serving-path journal?
//!
//! Synthesizes a sharded chaos run's event stream (coding groups of
//! k=2 with parity, periodic instance kills forcing decodes, a tail of
//! admission rejects), then times each pipeline stage in isolation:
//!
//! - `decode`    — binary codec, bytes → `Vec<TimedEvent>`;
//! - `replay`    — full invariant verification + byte-identical
//!                 re-encode (the `parm replay` path);
//! - `analyze`   — span trees + group fates + fault windows (the
//!                 `parm trace` path);
//! - `mine`      — `workload::Trace::from_journal` (the `parm mine`
//!                 path);
//! - `render`    — JSON report + Chrome trace-event export.
//!
//! Emits `bench_out/trace_mining.txt` with per-stage latency and
//! events-per-second throughput. Env knobs: PARM_BENCH_QUERIES
//! (default 20_000).

use std::time::Duration;

use parm::coordinator::journal::{self, EndTotals, Event, Recorder, TimedEvent};
use parm::coordinator::trace::{analyze, chrome, report, AnalyzeOpts};
use parm::util::stats;
use parm::workload::trace::Trace;

const K: u64 = 2;
const SHARDS: u64 = 2;

/// Deterministic synthetic run: `n` queries through k=2 coding groups
/// striped over two shard tags, every 16th group losing a slot to a
/// kill (decode + reconstructed outcome), plus a sprinkle of rejects.
/// Returns the event stream and the matching footer totals.
fn synth(n: u64) -> (Vec<TimedEvent>, EndTotals) {
    let mut ev = Vec::with_capacity(n as usize * 6 / 2);
    let mut totals = EndTotals::default();
    let mut ts = 0u64;
    let mut step = |ts: &mut u64| {
        *ts += 37;
        *ts
    };
    ev.push(TimedEvent {
        ts_us: 0,
        shard: 0,
        event: Event::Start { seed: 0xBE7C, mode: "parm".into(), shards: SHARDS },
    });
    let groups = n / K;
    for g in 0..groups {
        let shard = g % SHARDS;
        let qid = |slot: u64| (g / SHARDS) * K + slot;
        for slot in 0..K {
            ev.push(TimedEvent {
                ts_us: step(&mut ts),
                shard,
                event: Event::Submit { qid: qid(slot) },
            });
        }
        for slot in 0..K {
            ev.push(TimedEvent {
                ts_us: step(&mut ts),
                shard,
                event: Event::Dispatch { group: g, kind: 0, detail: slot, queries: 1 },
            });
        }
        ev.push(TimedEvent {
            ts_us: step(&mut ts),
            shard,
            event: Event::Dispatch { group: g, kind: 1, detail: 0, queries: 0 },
        });
        ev.push(TimedEvent {
            ts_us: step(&mut ts),
            shard,
            event: Event::Seal { group: g, k: K, r: 1 },
        });
        let killed = g % 16 == 7;
        if killed {
            ev.push(TimedEvent {
                ts_us: step(&mut ts),
                shard,
                event: Event::Fault { instance: 0, kind: 1, arg: 0 },
            });
            ev.push(TimedEvent {
                ts_us: step(&mut ts),
                shard,
                event: Event::Decode { group: g, slot: 0 },
            });
            totals.reconstructions += 1;
        }
        for slot in 0..K {
            let recovered = killed && slot == 0;
            let lat = if recovered { 9_000 } else { 2_000 };
            ev.push(TimedEvent {
                ts_us: step(&mut ts) + lat,
                shard,
                event: Event::Complete {
                    qid: qid(slot),
                    outcome: u8::from(recovered),
                    latency_us: lat,
                },
            });
            if recovered {
                totals.reconstructed += 1;
            } else {
                totals.native += 1;
            }
        }
        if g % 64 == 11 {
            ev.push(TimedEvent { ts_us: step(&mut ts), shard, event: Event::Reject { n: 1 } });
            totals.rejected += 1;
        }
    }
    // Timestamps above jump around (the +lat completes); journals are
    // globally non-decreasing, so sort before footing.
    ev.sort_by_key(|te| te.ts_us);
    totals.wall_us = ev.last().map_or(0, |te| te.ts_us);
    ev.push(TimedEvent {
        ts_us: totals.wall_us,
        shard: 0,
        event: Event::End {
            native: totals.native,
            reconstructed: totals.reconstructed,
            replica: totals.replica,
            defaulted: totals.defaulted,
            rejected: totals.rejected,
            reconstructions: totals.reconstructions,
            wall_us: totals.wall_us,
        },
    });
    (ev, totals)
}

/// Encode the synthetic stream through the real recorder (its clock
/// stamps the bytes; content is what the codec benches care about).
fn encode(events: &[TimedEvent], totals: &EndTotals) -> Vec<u8> {
    let rec = Recorder::start(0xBE7C, "parm", SHARDS);
    let tags: Vec<Recorder> = (0..SHARDS).map(|s| rec.tagged(s)).collect();
    for te in events {
        match &te.event {
            Event::Start { .. } | Event::End { .. } => {}
            e => tags[te.shard as usize].record(e),
        }
    }
    rec.finish_totals(totals)
}

fn main() {
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let (events, totals) = synth(n);
    let bytes = encode(&events, &totals);
    let n_events = events.len();
    println!(
        "trace-mining bench: {n} queries, {n_events} events, {} journal bytes",
        bytes.len()
    );

    let opts = AnalyzeOpts::default();
    // Sanity before timing: the synthetic journal verifies, and the
    // analysis sees every query with exact phase accounting.
    journal::replay(&bytes).expect("synthetic journal replays");
    let a = analyze(&events, &opts);
    assert_eq!(a.spans.len(), n as usize);
    assert_eq!(a.open_spans(), 0);
    assert_eq!(a.outcome_counts().reconstructed, totals.reconstructed);
    for s in &a.spans {
        let p = s.phases().expect("completed");
        assert_eq!(p.queue_us + p.seal_wait_us + p.decode_wait_us + p.tail_us, p.total_us);
    }
    let mined = Trace::from_journal(&events).expect("mines");
    assert_eq!(mined.len(), n as usize);

    let mut lines = vec![format!(
        "{:<28} {:>10} {:>10} {:>10} {:>14}",
        "stage", "p50 ms", "p99 ms", "mean ms", "events/s"
    )];
    let budget = Duration::from_millis(400);
    let mut row = |label: &str, s: &mut stats::Summary| {
        let line = format!(
            "{:<28} {:>10.2} {:>10.2} {:>10.2} {:>14.0}",
            label,
            s.median(),
            s.p99(),
            s.mean(),
            n_events as f64 / (s.mean() / 1e3)
        );
        println!("{line}");
        lines.push(line);
    };

    let mut s = stats::bench("decode", 3, 20, budget, || {
        std::hint::black_box(journal::decode(&bytes).unwrap());
    });
    row("decode (bytes -> events)", &mut s);

    let mut s = stats::bench("replay", 3, 20, budget, || {
        std::hint::black_box(journal::replay(&bytes).unwrap());
    });
    row("replay (verify + re-encode)", &mut s);

    let mut s = stats::bench("analyze", 3, 20, budget, || {
        std::hint::black_box(analyze(&events, &opts));
    });
    row("analyze (spans + windows)", &mut s);

    let mut s = stats::bench("mine", 3, 20, budget, || {
        std::hint::black_box(Trace::from_journal(&events).unwrap());
    });
    row("mine (journal -> Trace)", &mut s);

    let mut s = stats::bench("render-json", 3, 20, budget, || {
        std::hint::black_box(report::render_json(&a).to_string());
    });
    row("render (json report)", &mut s);

    let mut s = stats::bench("render-chrome", 3, 20, budget, || {
        std::hint::black_box(chrome::chrome_trace(&a));
    });
    row("render (chrome export)", &mut s);

    let _ = std::fs::create_dir_all("bench_out");
    let _ = std::fs::write("bench_out/trace_mining.txt", lines.join("\n"));
    println!("(wrote bench_out/trace_mining.txt)");
}

//! Ablation (§3.5): tolerating TWO concurrent unavailabilities with two
//! parity models (k=2, r=2). Both data outputs of each stripe are dropped
//! and reconstructed from the two parity outputs alone — the hardest
//! decode the framework supports. Compares against the r=1 single-loss
//! accuracy to show the cost of stacking parities.

use parm::artifacts::Manifest;
use parm::experiments::accuracy;

const DATASET: &str = "synthvision10";
const ARCH: &str = "microresnet";

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let dep = m.deployed(DATASET, ARCH)?;
    let p0 = m.parity(DATASET, ARCH, 2, "sum", 0)?;
    let p1 = m.parity(DATASET, ARCH, 2, "sum", 1)?;

    let r1 = accuracy::evaluate(&m, dep, p0, 7)?;
    let r2 = accuracy::evaluate_r2(&m, dep, p0, p1, 7)?;

    println!("=== §3.5 ablation: r=1 vs r=2 (k=2, {DATASET}/{ARCH}) ===");
    println!("{:<34} {:>8} {:>8}", "scenario", "A_a", "A_d");
    println!(
        "{:<34} {:>8.3} {:>8.3}",
        "r=1: one loss per stripe", r1.available, r1.degraded
    );
    println!(
        "{:<34} {:>8.3} {:>8.3}",
        "r=2: BOTH outputs lost", r2.available, r2.degraded
    );
    println!(
        "\nreading: with a second learned parity model ParM still recovers\n\
         useful predictions when an entire stripe goes dark — at lower\n\
         accuracy than the single-loss case, mirroring the paper's\n\
         redundancy/accuracy trade-off."
    );
    Ok(())
}

//! Cross-shard coding vs. per-shard ParM under whole-shard faults:
//! recovery rate as a function of how many entire fault domains die
//! mid-run.
//!
//! For each shard-fault count f, both tiers serve the same paced
//! multi-client workload from the same seed over the same 4-shard spec;
//! at 30% of the run, f whole shards are killed (every instance — for
//! ParM that includes its in-shard parity instances, because the shard
//! IS the fault domain). Intra-shard ParM then loses its killed shards'
//! queries to SLO defaults — data and parity die together — while the
//! cross-shard tier loses at most one slot per coding group and decodes
//! from the shared parity pool, ramping per-group r via the fleet
//! predictor when the losses register.
//!
//! Emits `bench_out/cross_shard.json` (recovery-rate vs.
//! shard-fault-count, per scheme) and asserts the headline: for f >= 1
//! the cross-shard tier recovers strictly more and defaults strictly
//! less than per-shard ParM under the same seed.
//!
//! Env knobs: PARM_BENCH_QUERIES (default 1600), PARM_BENCH_SHARD_FAULTS
//! (comma list, default "0,1,2").

use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedClient, ShardedFrontend};
use parm::experiments::latency;
use parm::util::json::Json;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

const SHARDS: usize = 4;
const M: usize = 2;
const K: usize = 2;
const R_MAX: usize = 2;
const CLIENTS: usize = 8;
const SEED: u64 = 0xC5B3;

struct Row {
    scheme: &'static str,
    shard_faults: usize,
    resolved: u64,
    reconstructed: u64,
    defaulted: u64,
    recovery_rate: f64,
    parity_overhead: f64,
    p50_ms: f64,
    p999_ms: f64,
}

impl Row {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme)
            .set("shard_faults", self.shard_faults)
            .set("resolved", self.resolved as usize)
            .set("reconstructed", self.reconstructed as usize)
            .set("defaulted", self.defaulted as usize)
            .set("recovery_rate", self.recovery_rate)
            .set("parity_overhead", self.parity_overhead)
            .set("p50_ms", self.p50_ms)
            .set("p999_ms", self.p999_ms)
    }
}

/// Paced Poisson clients against any tier minting `ShardedClient`s;
/// returns once every accepted query resolved (SLO-backstopped).
fn drive(clients: Vec<ShardedClient>, queries: &[parm::tensor::Tensor], per: u64, per_rate: f64) {
    let mut joins = Vec::new();
    for (c, client) in clients.into_iter().enumerate() {
        let queries = queries.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(SEED ^ 0xD51 ^ (c as u64) << 9);
            let mut due = Instant::now();
            let mut accepted = 0u64;
            for i in 0..per {
                due += Duration::from_secs_f64(rng.exponential(per_rate));
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if client.submit(queries[i as usize % queries.len()].clone()).is_ok() {
                    accepted += 1;
                }
                let _ = client.poll();
            }
            while client.stats().resolved < accepted {
                if client.next(Duration::from_secs(8)).is_none() {
                    break;
                }
            }
        }));
    }
    for j in joins {
        let _ = j.join();
    }
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_600);
    let fault_counts: Vec<usize> = std::env::var("PARM_BENCH_SHARD_FAULTS")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0, 1, 2]);

    let models = latency::load_models(&m, 1, K, R_MAX, false)?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let rate = 320.0;
    let per = n / CLIENTS as u64;
    let per_rate = rate / CLIENTS as f64;
    let run_secs = per as f64 / per_rate;
    let kill_after = Duration::from_secs_f64(run_secs * 0.3);
    let spec = ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None };
    let slo = Duration::from_millis(1500);

    println!(
        "cross-shard sweep: {n} queries, {CLIENTS} clients, {SHARDS} shards (m={M}), \
         whole-shard fault counts {fault_counts:?}"
    );
    println!(
        "{:<12} {:>7} {:>9} {:>9} {:>9} {:>9} {:>10} {:>9} {:>10}",
        "scheme", "faults", "resolved", "recon", "default", "recovery", "overhead", "p50(ms)", "p99.9(ms)"
    );

    let mut rows: Vec<Row> = Vec::new();
    for &f in &fault_counts {
        let f = f.min(SHARDS - 1); // keep at least one live shard
        // Deterministic victim set: the highest-numbered shards.
        let victims: Vec<usize> = (SHARDS - f..SHARDS).collect();

        // --- cross-shard coding tier ---
        let mut cfg = ServiceConfig::defaults(
            Mode::CrossShard {
                k: K,
                r_min: 1,
                r_max: R_MAX,
                halflife: Duration::from_millis(300),
            },
            &GPU,
        );
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = SEED + f as u64;
        cfg.slo = Some(slo);
        let tier = CrossShardFrontend::start(cfg, spec, &models, &source.queries[0])?;
        let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
        let killer = {
            let plans: Vec<_> = victims.iter().map(|&s| tier.fault_plan(s)).collect();
            std::thread::spawn(move || {
                std::thread::sleep(kill_after);
                for p in &plans {
                    for i in 0..M {
                        p.kill(i);
                    }
                }
            })
        };
        drive(clients, &source.queries, per, per_rate);
        let _ = killer.join();
        tier.flush_open_groups();
        let res = tier.shutdown()?;
        let t = &res.telemetry;
        let overhead = if t.groups_sealed > 0 {
            t.parity_jobs as f64 / t.groups_sealed as f64
        } else {
            0.0
        };
        let mut metrics = res.fleet.merged.metrics;
        rows.push(Row {
            scheme: "cross-shard",
            shard_faults: f,
            resolved: metrics.total(),
            reconstructed: metrics.reconstructed,
            defaulted: metrics.defaulted,
            recovery_rate: recovery(metrics.reconstructed, metrics.defaulted),
            parity_overhead: overhead,
            p50_ms: metrics.latency.median(),
            p999_ms: metrics.latency.p999(),
        });
        print_row(rows.last().unwrap());

        // --- baseline: per-shard ParM, same seed and victim shards ---
        let mut cfg = ServiceConfig::defaults(
            Mode::Parm { k: K, encoders: vec![Encoder::sum(K)] },
            &GPU,
        );
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = SEED + f as u64;
        cfg.slo = Some(slo);
        let tier = ShardedFrontend::start(cfg, spec, &models, &source.queries[0])?;
        let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
        let per_shard_instances = M + (M + K - 1) / K; // deployed + parity pool
        let killer = {
            let plans: Vec<_> = victims.iter().map(|&s| tier.fault_plan(s)).collect();
            std::thread::spawn(move || {
                std::thread::sleep(kill_after);
                for p in &plans {
                    for i in 0..per_shard_instances {
                        p.kill(i);
                    }
                }
            })
        };
        drive(clients, &source.queries, per, per_rate);
        let _ = killer.join();
        let res = tier.shutdown()?;
        let mut metrics = res.merged.metrics;
        rows.push(Row {
            scheme: "parm",
            shard_faults: f,
            resolved: metrics.total(),
            reconstructed: metrics.reconstructed,
            defaulted: metrics.defaulted,
            recovery_rate: recovery(metrics.reconstructed, metrics.defaulted),
            parity_overhead: 1.0,
            p50_ms: metrics.latency.median(),
            p999_ms: metrics.latency.p999(),
        });
        print_row(rows.last().unwrap());
    }

    let json = Json::Arr(rows.iter().map(Row::to_json).collect());
    let _ = std::fs::create_dir_all("bench_out");
    let path = "bench_out/cross_shard.json";
    if std::fs::write(path, json.to_string()).is_ok() {
        println!("(wrote {path})");
    }

    // Headline: whole-shard faults that drown per-shard ParM in SLO
    // defaults are absorbed by the cross-shard code.
    for &f in &fault_counts {
        if f == 0 {
            continue;
        }
        let f = f.min(SHARDS - 1);
        let pick = |scheme: &str| {
            rows.iter().find(|r| r.scheme == scheme && r.shard_faults == f).unwrap()
        };
        let (cross, parm) = (pick("cross-shard"), pick("parm"));
        assert!(
            parm.defaulted > 0,
            "faults={f}: whole-shard kills must cost per-shard ParM defaults"
        );
        assert!(
            cross.defaulted < parm.defaulted,
            "faults={f}: cross-shard must lose strictly less \
             ({} vs {} defaults)",
            cross.defaulted,
            parm.defaulted
        );
        assert!(
            cross.recovery_rate > parm.recovery_rate,
            "faults={f}: cross-shard recovery rate must dominate \
             ({:.3} vs {:.3})",
            cross.recovery_rate,
            parm.recovery_rate
        );
        println!(
            "faults={f}: cross-shard defaulted {} (recovery {:.3}) vs parm {} ({:.3})",
            cross.defaulted, cross.recovery_rate, parm.defaulted, parm.recovery_rate
        );
    }
    println!("ok: cross-shard coding absorbs whole-shard faults that sink per-shard ParM");
    Ok(())
}

/// Of the queries that lost their own prediction, the fraction decode
/// brought back (1.0 when nothing was lost at all).
fn recovery(reconstructed: u64, defaulted: u64) -> f64 {
    let lost = reconstructed + defaulted;
    if lost == 0 {
        return 1.0;
    }
    reconstructed as f64 / lost as f64
}

fn print_row(r: &Row) {
    println!(
        "{:<12} {:>7} {:>9} {:>9} {:>9} {:>9.3} {:>10.3} {:>9.3} {:>10.3}",
        r.scheme,
        r.shard_faults,
        r.resolved,
        r.reconstructed,
        r.defaulted,
        r.recovery_rate,
        r.parity_overhead,
        r.p50_ms,
        r.p999_ms,
    );
}

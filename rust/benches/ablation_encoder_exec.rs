//! Ablation (§3.2 design space): where should the encoder run?
//!
//! ParM's frontend encoder is deliberately trivial (a sum), so it can run
//! natively on the frontend CPU. An alternative is shipping it as an XLA
//! program (our L1 Pallas sum-encoder kernel, AOT-lowered like the
//! models) and invoking it via PJRT. This bench measures both paths for
//! k = 2, 3, 4 on the latency workload's 64x64x3 queries — quantifying
//! the paper's implicit claim that simple encoders belong on the
//! frontend, not on accelerator-style execution paths (dispatch overhead
//! dominates at these sizes).

use std::time::Duration;

use parm::artifacts::Manifest;
use parm::coordinator::encoder::Encoder;
use parm::runtime::engine::Executable;
use parm::tensor::Tensor;
use parm::util::rng::Pcg64;
use parm::util::stats;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let mut rng = Pcg64::new(0xE2C);

    println!("=== §3.2 ablation: native frontend encoder vs PJRT-executed encoder ===");
    println!("{:<26} {:>4} {:>12} {:>12}", "path", "k", "p50(us)", "p99(us)");
    for k in [2usize, 3, 4] {
        let queries: Vec<Tensor> = (0..k)
            .map(|_| {
                let n = 64 * 64 * 3;
                Tensor::new(vec![64, 64, 3], (0..n).map(|_| rng.next_f32()).collect()).unwrap()
            })
            .collect();
        let qrefs: Vec<&Tensor> = queries.iter().collect();

        // Native path (what the coordinator actually uses).
        let enc = Encoder::sum(k);
        let mut s = stats::bench("native", 50, 2_000, Duration::from_millis(250), || {
            std::hint::black_box(enc.encode(&qrefs).unwrap());
        });
        println!(
            "{:<26} {:>4} {:>12.1} {:>12.1}",
            "native (frontend CPU)", k, s.median() * 1e3, s.p99() * 1e3
        );

        // PJRT path: stack k queries, execute the exported Pallas program.
        let entry = match m.model(&format!("encoder.sum.k{k}")) {
            Ok(e) => e,
            Err(_) => {
                println!("(encoder artifacts missing — rerun `make artifacts`)");
                continue;
            }
        };
        let exe = Executable::load(
            m.hlo_path(entry, 1)?,
            &entry.name,
            &entry.input_shape[1..],
            entry.input_shape[0],
            entry.out_dim,
        )?;
        let stacked = Tensor::batch(&queries)?;
        let mut s = stats::bench("pjrt", 20, 500, Duration::from_millis(250), || {
            std::hint::black_box(exe.run_raw(&stacked).unwrap());
        });
        println!(
            "{:<26} {:>4} {:>12.1} {:>12.1}",
            "pjrt (Pallas sum kernel)", k, s.median() * 1e3, s.p99() * 1e3
        );
    }
    println!("\nreading: at query sizes the dispatch/marshalling overhead of an\n\
              accelerator-style call dwarfs the native sum — the paper's simple\n\
              frontend encoders are the right design point.");
    Ok(())
}

//! Adaptive redundancy sweep: fault intensity vs. achieved recovery and
//! parity overhead, ParM (fixed r=1) against the rateless scheme
//! (predictor-driven r in [1, r_max]).
//!
//! For each fault intensity f (how many deployed instances become
//! undetected zombies a quarter of the way into the run), both schemes
//! serve the same open-loop Poisson workload with the same seed and the
//! same fault plan. The interesting regime is f >= 2 with k = 2: a
//! coding group can then lose *two* slots, which fixed-r ParM can never
//! reconstruct (those queries fall to the SLO default) while the
//! rateless scheme ramps to two parities per group and recovers them —
//! at an overhead that decays back to the floor when the fault clears.
//!
//! Emits `bench_out/adaptive_redundancy.json` and asserts the headline:
//! with redundancy_max >= 2, rateless recovers strictly more unavailable
//! predictions than ParM under the same multi-instance fault plan.
//!
//! Each run is also sampled through the telemetry registry
//! ([`parm::telemetry::series`]): the session's `parm_session_window_*`
//! gauges plus the adaptive scheme's operating point (`last_r`,
//! `unavailability`, `parity_overhead` — zeros under fixed-topology
//! ParM, which registers no scheme gauges). The highest-intensity pair
//! lands in `bench_out/adaptive_redundancy_{parm,rateless}_timeseries.json`,
//! showing the rateless ramp-up across the fault and the overhead decay
//! after it.
//!
//! Env knobs: PARM_BENCH_QUERIES (default 2500), PARM_BENCH_FAULTS
//! (comma list, default "0,1,2").

use std::time::Duration;

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::telemetry::series::Capture;
use parm::util::json::Json;
use parm::workload::QuerySource;

const K: usize = 2;
const R_MAX: usize = 2;
const M: usize = 4;

struct Row {
    scheme: &'static str,
    faults: usize,
    resolved: u64,
    reconstructed: u64,
    defaulted: u64,
    parity_overhead: f64,
    p50_ms: f64,
    p999_ms: f64,
}

impl Row {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme)
            .set("faults", self.faults)
            .set("resolved", self.resolved as usize)
            .set("reconstructed", self.reconstructed as usize)
            .set("defaulted", self.defaulted as usize)
            .set("parity_overhead", self.parity_overhead)
            .set("p50_ms", self.p50_ms)
            .set("p999_ms", self.p999_ms)
    }
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_500);
    let intensities: Vec<usize> = std::env::var("PARM_BENCH_FAULTS")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0, 1, 2]);

    let models = latency::load_models(&m, 1, K, R_MAX, false)?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let probe = source.queries[0].clone();
    let mean = parm::coordinator::service::measure_service(&models.deployed, &probe, 20);
    let profile = &hardware::GPU;
    let rate = 0.5 * M as f64 / (mean.as_secs_f64() * profile.exec_scale.max(1.0));
    let run_secs = n as f64 / rate;

    println!(
        "adaptive redundancy sweep: {n} queries at {rate:.0} qps, m={M} k={K}, \
         fault intensities {intensities:?}"
    );
    println!(
        "{:<10} {:>7} {:>9} {:>9} {:>9} {:>10} {:>9} {:>10}",
        "scheme", "faults", "resolved", "recon", "default", "overhead", "p50(ms)", "p99.9(ms)"
    );

    let mut rows: Vec<Row> = Vec::new();
    let max_faults = intensities.iter().copied().max().unwrap_or(0);
    let sample = Duration::from_millis(250);
    for &faults in &intensities {
        let schedule: Vec<(usize, Duration, Duration)> = (0..faults.min(M))
            .map(|i| (i, Duration::from_secs_f64(run_secs * 0.25), Duration::ZERO))
            .collect();
        for (mode, tag) in [
            (Mode::Parm { k: K, encoders: vec![Encoder::sum(K)] }, "parm"),
            (
                Mode::Rateless {
                    k: K,
                    r_min: 1,
                    r_max: R_MAX,
                    halflife: Duration::from_millis(400),
                },
                "rateless",
            ),
        ] {
            let mut cfg = ServiceConfig::defaults(mode, profile);
            cfg.m = M;
            cfg.shuffles = 2;
            cfg.seed = 0xADA7 + faults as u64;
            cfg.slo = Some(Duration::from_secs(1)); // unrecoverable queries default
            cfg.fault_schedule = schedule.clone();

            let mut handle = ServiceBuilder::new(cfg).build(&models, &source.queries[0])?;
            // Sample the run's timeline off the session's metric
            // registry — the same gauges an operator would scrape.
            let registry = handle.registry();
            let mut cap = Capture::session(&registry, sample)
                .with_extra("last_r", "parm_scheme_last_r")
                .with_extra("unavailability", "parm_scheme_unavailability")
                .with_extra("parity_overhead", "parm_scheme_parity_overhead");
            handle.run_open_loop_observed(&source.queries, n, rate, Some(sample), &mut |_t, w| {
                parm::telemetry::publish_window(&registry, "parm_session_window_", &[], &w);
                cap.sample();
            });
            let _ = handle.drain();
            if faults == max_faults {
                handle.publish_telemetry();
                cap.sample();
                cap.emit(&format!("adaptive_redundancy_{tag}_timeseries"));
            }
            let telemetry = handle.scheme_telemetry();
            let res = handle.shutdown();
            let overhead = match telemetry {
                Some(t) if t.groups_sealed > 0 => t.parity_jobs as f64 / t.groups_sealed as f64,
                // Fixed-topology ParM: one parity per group by construction.
                _ => 1.0,
            };
            let mut metrics = res.metrics;
            let row = Row {
                scheme: tag,
                faults,
                resolved: metrics.total(),
                reconstructed: metrics.reconstructed,
                defaulted: metrics.defaulted,
                parity_overhead: overhead,
                p50_ms: metrics.latency.median(),
                p999_ms: metrics.latency.p999(),
            };
            println!(
                "{:<10} {:>7} {:>9} {:>9} {:>9} {:>10.3} {:>9.3} {:>10.3}",
                row.scheme,
                row.faults,
                row.resolved,
                row.reconstructed,
                row.defaulted,
                row.parity_overhead,
                row.p50_ms,
                row.p999_ms,
            );
            rows.push(row);
        }
    }

    let json = Json::Arr(rows.iter().map(Row::to_json).collect());
    let _ = std::fs::create_dir_all("bench_out");
    let path = "bench_out/adaptive_redundancy.json";
    if std::fs::write(path, json.to_string()).is_ok() {
        println!("(wrote {path})");
    }

    // Headline checks (the acceptance criterion of the adaptive-redundancy
    // subsystem): under a multi-instance fault plan, rateless with
    // r_max >= 2 recovers strictly more unavailable predictions than
    // fixed-r ParM, and its overhead stays adaptive (between the floor
    // and the ceiling, not pinned at either).
    for &faults in &intensities {
        if faults < 2 {
            continue;
        }
        let recon = |tag: &str| {
            rows.iter()
                .find(|r| r.scheme == tag && r.faults == faults)
                .map(|r| r.reconstructed)
                .unwrap_or(0)
        };
        let (parm, rateless) = (recon("parm"), recon("rateless"));
        assert!(
            rateless > parm,
            "faults={faults}: rateless must recover strictly more than ParM \
             (rateless {rateless} vs parm {parm})"
        );
        let defaulted = |tag: &str| {
            rows.iter()
                .find(|r| r.scheme == tag && r.faults == faults)
                .map(|r| r.defaulted)
                .unwrap_or(0)
        };
        println!(
            "faults={faults}: rateless recovered {rateless} vs parm {parm} \
             (defaults {} vs {})",
            defaulted("rateless"),
            defaulted("parm"),
        );
    }
    if let Some(r) = rows.iter().find(|r| r.scheme == "rateless" && r.faults >= 2) {
        assert!(
            r.parity_overhead > 1.0 && r.parity_overhead < R_MAX as f64,
            "overhead must adapt between the floor and ceiling, got {}",
            r.parity_overhead
        );
    }
    println!("ok: rateless recovery dominates fixed-r ParM under multi-instance faults");
    Ok(())
}

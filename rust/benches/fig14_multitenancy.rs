//! Figure 14: light multitenancy (§5.2.4) — a co-located tenant on one
//! ninth of instances at <5% load, no network imbalance. ParM vs
//! Equal-Resources across query rates on the GPU-profile cluster.
//!
//! Also emits a fault-event **time series**
//! (`bench_out/fig14_timeseries.json`, via the shared
//! `run_fault_timeseries` scaffold): the live windowed tail sampled
//! through a tenancy-only run with one deployed instance killed mid-way.
//!
//! Env knobs: PARM_BENCH_QUERIES (default 12000),
//! PARM_BENCH_TS_QUERIES (default 6000), PARM_BENCH_TS_SAMPLE_MS (250).

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::experiments::latency;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);

    let rows = latency::parm_vs_equal_resources(
        &m,
        &hardware::GPU,
        2,
        1,
        n,
        &[0.3, 0.45, 0.6],
        0,    // no shuffles —
        true, // — tenancy is the only imbalance
        0xF16_14,
    )?;
    latency::emit("fig14_multitenancy", &rows);

    // Time series: tenancy-only imbalance across a fault event.
    latency::run_fault_timeseries(
        &m, "fig14_timeseries", "parm-tenancy-fault", 0.45, 0, true, 0xF16_14,
    )?;
    Ok(())
}

//! §5.2.5: latency of ParM's own components — encoding and decoding — for
//! k = 2, 3, 4 on the latency workload's tensors (64x64x3 queries,
//! 1000-float predictions). The paper reports 93-193 us encode and
//! 8-19 us decode; the point to reproduce is that both are orders of
//! magnitude below model inference (tens of ms), i.e. ParM's codes are
//! effectively free on the request path.

use std::time::Duration;

use parm::coordinator::decoder;
use parm::coordinator::encoder::Encoder;
use parm::tensor::Tensor;
use parm::util::rng::Pcg64;
use parm::util::stats;

fn rand_tensor(rng: &mut Pcg64, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
    Tensor::new(shape, data).unwrap()
}

fn main() {
    parm::util::logging::init();
    let mut rng = Pcg64::new(0x5257);
    println!("=== §5.2.5 component latency (64x64x3 queries, 1000-f32 preds) ===");
    println!(
        "{:<24} {:>4} {:>12} {:>12} {:>12}",
        "component", "k", "p50(us)", "p99(us)", "mean(us)"
    );
    let mut lines = Vec::new();
    for k in [2usize, 3, 4] {
        let queries: Vec<Tensor> =
            (0..k).map(|_| rand_tensor(&mut rng, vec![64, 64, 3])).collect();
        let qrefs: Vec<&Tensor> = queries.iter().collect();

        for (enc, name) in [
            (Encoder::sum(k), "encode/sum"),
            (Encoder::concat(k), "encode/concat"),
        ] {
            if matches!(enc, Encoder::Concat { k } if k == 3) {
                continue; // concat needs k=2 or square k
            }
            let mut s = stats::bench(name, 50, 2_000, Duration::from_millis(300), || {
                std::hint::black_box(enc.encode(&qrefs).unwrap());
            });
            let line = format!(
                "{:<24} {:>4} {:>12.1} {:>12.1} {:>12.1}",
                name,
                k,
                s.median() * 1e3,
                s.p99() * 1e3,
                s.mean() * 1e3
            );
            println!("{line}");
            lines.push(line);
        }

        // Decode: parity output + (k-1) available 1000-float predictions.
        let outs: Vec<Option<Tensor>> = (0..k)
            .map(|i| if i == 0 { None } else { Some(rand_tensor(&mut rng, vec![1000])) })
            .collect();
        let parity_out = rand_tensor(&mut rng, vec![1000]);
        let weights = vec![1.0f32; k];
        let mut s = stats::bench("decode/sub", 50, 5_000, Duration::from_millis(300), || {
            std::hint::black_box(
                decoder::decode_r1(&weights, &parity_out, &outs, 0).unwrap(),
            );
        });
        let line = format!(
            "{:<24} {:>4} {:>12.1} {:>12.1} {:>12.1}",
            "decode/sub",
            k,
            s.median() * 1e3,
            s.p99() * 1e3,
            s.mean() * 1e3
        );
        println!("{line}");
        lines.push(line);
    }
    let _ = std::fs::create_dir_all("bench_out");
    let _ = std::fs::write("bench_out/component_latency.txt", lines.join("\n"));
    println!("(wrote bench_out/component_latency.txt)");
}

//! §5.2.3: ParM vs Equal-Resources at batch sizes 1, 2, 4 on the
//! GPU-profile cluster. Rates scale with the throughput gain of batching
//! (the paper scales 300 -> 460 -> 584 qps; we scale by measured batched
//! service time).

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::experiments::latency;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);

    let mut rows = Vec::new();
    for batch in [1usize, 2, 4] {
        let mut r = latency::parm_vs_equal_resources(
            &m,
            &hardware::GPU,
            2,
            batch,
            n,
            &[0.55],
            4,
            false,
            0xBA7C4 + batch as u64,
        )?;
        for row in &mut r {
            row.label = format!("{} b={batch}", row.label);
        }
        rows.extend(r);
    }
    latency::emit("batch_size", &rows);
    Ok(())
}

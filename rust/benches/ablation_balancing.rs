//! Ablation (§5.1 "Load balancing"): single-queue vs round-robin
//! dispatch. The paper notes single-queue is optimal for mean response
//! time and that sub-optimal balancers make ParM look even better —
//! round-robin keeps feeding slowed instances, so Equal-Resources' tail
//! inflates further while ParM's reconstructions cap it.

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::experiments::latency;
use parm::runtime::pool::Balancing;
use parm::workload::QuerySource;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let k = 2usize;
    let models = latency::load_models(&m, 1, k, 1, false)?;
    let mean = parm::coordinator::service::measure_service(
        &models.deployed,
        &parm::tensor::Tensor::batch(&[source.queries[0].clone()])?,
        20,
    );
    let capacity = GPU.default_m as f64 / mean.as_secs_f64();
    let rate = 0.5 * capacity;

    let mut rows = Vec::new();
    for (bal, bname) in [
        (Balancing::SingleQueue, "single-queue"),
        (Balancing::RoundRobin, "round-robin"),
    ] {
        for (mode, tag) in [
            (Mode::Parm { k, encoders: vec![Encoder::sum(k)] }, "parm"),
            (Mode::EqualResources { k }, "equal-res"),
        ] {
            let mut cfg = ServiceConfig::defaults(mode, &GPU);
            cfg.balancing = bal;
            cfg.seed = 0xBA1;
            rows.push(latency::run_point(
                &cfg,
                &models,
                &source,
                n,
                rate,
                &format!("{tag}[{bname}]"),
            )?);
        }
    }
    latency::emit("ablation_balancing", &rows);
    Ok(())
}

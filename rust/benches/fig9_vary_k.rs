//! Figure 9: degraded-mode accuracy A_d for k = 2, 3, 4 (sum encoder)
//! across datasets, plus §4.2.3 / Figure 10: the task-specific concat
//! encoder (k = 2, 4) on the CIFAR-10 stand-in.

use parm::artifacts::Manifest;
use parm::experiments::accuracy;
use parm::util::json::Json;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;

    println!("=== Figure 9 (sum) + Figure 10 (concat): A_d vs k ===");
    println!(
        "{:<16} {:<13} {:>4} {:>9} {:>8} {:>8} {:>9}",
        "dataset", "arch", "k", "encoder", "A_a", "A_d", "default"
    );
    let mut out = Vec::new();
    let mut parities: Vec<_> = m
        .models
        .iter()
        .filter(|x| x.role == "parity" && x.r_index == 0 && !x.name.contains("1000"))
        .collect();
    parities.sort_by(|a, b| {
        (&a.dataset, &a.arch, &a.encoder, a.k).cmp(&(&b.dataset, &b.arch, &b.encoder, b.k))
    });
    for model in parities {
        let dep = m.deployed(&model.dataset, &model.arch)?;
        let r = accuracy::evaluate(&m, dep, model, 7)?;
        println!(
            "{:<16} {:<13} {:>4} {:>9} {:>8.3} {:>8.3} {:>9.3}",
            r.dataset, r.arch, r.k, r.encoder, r.available, r.degraded,
            r.default_baseline
        );
        out.push(
            Json::obj()
                .set("dataset", r.dataset.as_str())
                .set("arch", r.arch.as_str())
                .set("k", r.k)
                .set("encoder", r.encoder.as_str())
                .set("available", r.available)
                .set("degraded", r.degraded)
                .set("default", r.default_baseline),
        );
    }
    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/fig9_vary_k.json", Json::Arr(out).to_string())?;
    println!("(wrote bench_out/fig9_vary_k.json)");
    Ok(())
}

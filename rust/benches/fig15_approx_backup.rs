//! Figure 15: ParM vs the approximate-backup-model alternative (§5.2.6).
//! The approx pool has m/k instances of a cheaper model that is NOT
//! k-times faster, so every query replicated to it queues — its tail
//! blows up as the rate approaches (pool capacity), while ParM's parity
//! pool only sees 1/k of the rate and keeps pace.

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::experiments::latency;
use parm::workload::QuerySource;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let k = 2usize;
    let models = latency::load_models(&m, 1, k, 1, true)?;
    let mean = parm::coordinator::service::measure_service(
        &models.deployed,
        &parm::tensor::Tensor::batch(&[source.queries[0].clone()])?,
        20,
    );
    let capacity = GPU.default_m as f64 / mean.as_secs_f64();

    let mut rows = Vec::new();
    for util in [0.3f64, 0.45, 0.6] {
        let rate = util * capacity;
        for (mode, tag) in [
            (Mode::Parm { k, encoders: vec![Encoder::sum(k)] }, "parm"),
            (Mode::ApproxBackup { k }, "approx-backup"),
        ] {
            let mut cfg = ServiceConfig::defaults(mode, &GPU);
            cfg.seed = 0xF16_15;
            rows.push(latency::run_point(
                &cfg,
                &models,
                &source,
                n,
                rate,
                &format!("{tag}[util={util:.2}]"),
            )?);
        }
    }
    latency::emit("fig15_approx_backup", &rows);
    Ok(())
}

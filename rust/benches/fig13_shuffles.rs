//! Figure 13: ParM vs Equal-Resources under varying network imbalance —
//! 2, 3, 4, 5 concurrent background shuffles on the GPU-profile cluster.
//!
//! Also emits a fault-event **time series** (`bench_out/fig13_timeseries.json`,
//! via the shared `run_fault_timeseries` scaffold): the live windowed
//! tail sampled through a run at the heaviest shuffle load with one
//! deployed instance killed mid-way, so the shuffle-imbalance story can
//! be read as a timeline.
//!
//! Env knobs: PARM_BENCH_QUERIES (default 12000),
//! PARM_BENCH_TS_QUERIES (default 6000), PARM_BENCH_TS_SAMPLE_MS (250).

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::experiments::latency;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);

    let mut rows = Vec::new();
    for shuffles in [2usize, 3, 4, 5] {
        let mut r = latency::parm_vs_equal_resources(
            &m,
            &hardware::GPU,
            2,
            1,
            n,
            &[0.55],
            shuffles,
            false,
            0xF16_13 + shuffles as u64,
        )?;
        for row in &mut r {
            row.label = format!("{} sh={shuffles}", row.label);
        }
        rows.extend(r);
    }
    latency::emit("fig13_shuffles", &rows);

    // Time series at the sweep's heaviest imbalance (5 shuffles).
    latency::run_fault_timeseries(
        &m, "fig13_timeseries", "parm-sh5-fault", 0.42, 5, false, 0xF16_13,
    )?;
    Ok(())
}

//! Figure 13: ParM vs Equal-Resources under varying network imbalance —
//! 2, 3, 4, 5 concurrent background shuffles on the GPU-profile cluster.

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::experiments::latency;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);

    let mut rows = Vec::new();
    for shuffles in [2usize, 3, 4, 5] {
        let mut r = latency::parm_vs_equal_resources(
            &m,
            &hardware::GPU,
            2,
            1,
            n,
            &[0.55],
            shuffles,
            false,
            0xF16_13 + shuffles as u64,
        )?;
        for row in &mut r {
            row.label = format!("{} sh={shuffles}", row.label);
        }
        rows.extend(r);
    }
    latency::emit("fig13_shuffles", &rows);
    Ok(())
}

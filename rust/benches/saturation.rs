//! Saturation rig for the submit→dispatch→complete hot path (ROADMAP
//! item 2): closed-loop clients hammer one serving shard with near-zero
//! simulated worker time, so coordination overhead — batcher, coding
//! bookkeeping, completion fan-out, admission accounting — is the
//! bottleneck being measured, not the (synthetic) model.
//!
//! For each client count in the sweep, `PARM_BENCH_PIPELINE` queries per
//! client are kept in flight for `PARM_BENCH_SECS` seconds; sustained
//! qps is counted over the post-warmup span and the p99.9 comes from the
//! session's own sliding window. The sweep point and its measured
//! throughput are published into the session's metric registry
//! (`parm_bench_*` gauges), so the `telemetry::series::Capture` rows in
//! `bench_out/throughput.json` carry `clients` / `phase_qps` /
//! `phase_p999_ms` columns next to the ordinary window columns —
//! `scripts/perf_compare.sh` gates on `phase_qps`.
//!
//! Knobs: `PARM_BENCH_CLIENTS` (comma list, default `1,2,4,8`),
//! `PARM_BENCH_SECS` (per phase, default 2), `PARM_BENCH_PIPELINE`
//! (in-flight per client, default 8).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::{AdmissionPolicy, ServingFrontend};
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::telemetry::series::Capture;
use parm::workload::QuerySource;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let manifest = Manifest::load_default()?;
    let models = latency::load_models(&manifest, 1, 2, 1, false)?;
    let source =
        QuerySource::from_dataset(&manifest, manifest.dataset(latency::LATENCY_DATASET)?)?;
    let query = source.queries[0].clone();

    let clients_sweep: Vec<usize> = std::env::var("PARM_BENCH_CLIENTS")
        .unwrap_or_else(|_| "1,2,4,8".to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let phase_secs: f64 = env_or("PARM_BENCH_SECS", 2.0);
    let pipeline: usize = env_or("PARM_BENCH_PIPELINE", 8);

    // One shard, coding on (ParM k=2 r=1 — the bookkeeping-heavy path),
    // batch size 1 (maximum per-query coordination work), and all
    // simulated delays compressed to zero so the serving substrate is
    // the only cost left.
    let mut cfg = ServiceConfig::defaults(
        Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
        &hardware::GPU,
    );
    cfg.m = 4;
    cfg.batch_size = 1;
    cfg.batch_timeout = Duration::from_millis(1);
    cfg.shuffles = 0;
    cfg.time_scale = 0.0;
    cfg.seed = 0x5A70;
    cfg.metrics_window = Duration::from_secs(1);
    cfg.telemetry_every = Duration::from_millis(50);
    cfg.admission = AdmissionPolicy::Unbounded;

    let registry = cfg.telemetry.clone();
    let g_clients = registry.gauge("parm_bench_clients", "Closed-loop clients this phase.", &[]);
    let g_qps =
        registry.gauge("parm_bench_phase_qps", "Sustained qps measured for the phase.", &[]);
    let g_p999 =
        registry.gauge("parm_bench_phase_p999_ms", "Windowed p99.9 at the phase end.", &[]);

    let handle = ServiceBuilder::new(cfg).build(&models, &query)?;
    let frontend = ServingFrontend::start_with_window(
        handle,
        AdmissionPolicy::Unbounded,
        Duration::from_secs(1),
    );

    let mut cap = Capture::session(&registry, Duration::from_millis(250))
        .with_extra("clients", "parm_bench_clients")
        .with_extra("phase_qps", "parm_bench_phase_qps")
        .with_extra("phase_p999_ms", "parm_bench_phase_p999_ms");

    println!("{:>8} {:>12} {:>12} {:>12}", "clients", "qps/shard", "p99(ms)", "p99.9(ms)");
    let mut best_qps = 0.0f64;
    let mut offered_total = 0u64;
    for &clients in &clients_sweep {
        g_clients.set(clients as f64);
        cap.mark(&format!("clients={clients}"));
        let stop = Arc::new(AtomicBool::new(false));
        let measuring = Arc::new(AtomicBool::new(false));
        let measured = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();
        for _ in 0..clients {
            let client = frontend.client();
            let q = query.clone();
            let stop = stop.clone();
            let measuring = measuring.clone();
            let measured = measured.clone();
            threads.push(std::thread::spawn(move || {
                let mut in_flight = 0usize;
                let mut submitted = 0u64;
                let mut resolved = 0u64;
                loop {
                    while !stop.load(Ordering::Relaxed) && in_flight < pipeline {
                        if client.submit(q.clone()).is_ok() {
                            in_flight += 1;
                            submitted += 1;
                        }
                    }
                    if in_flight == 0 {
                        break;
                    }
                    if let Some(_r) = client.next(Duration::from_millis(200)) {
                        in_flight -= 1;
                        resolved += 1;
                        let mut got = 1u64;
                        while let Some(_r) = client.try_next() {
                            in_flight -= 1;
                            resolved += 1;
                            got += 1;
                        }
                        if measuring.load(Ordering::Relaxed) {
                            measured.fetch_add(got, Ordering::Relaxed);
                        }
                    } else if stop.load(Ordering::Relaxed) {
                        // Nothing arrived for 200 ms after the phase
                        // ended: whatever is left resolves via drain at
                        // shutdown; stop waiting for it here.
                        break;
                    }
                }
                (submitted, resolved)
            }));
        }
        // Warm up for a quarter of the phase, then measure the rest.
        let warmup = Duration::from_secs_f64(phase_secs * 0.25);
        let measure = Duration::from_secs_f64(phase_secs * 0.75);
        let spin = |dur: Duration, cap: &mut Capture| {
            let until = Instant::now() + dur;
            while Instant::now() < until {
                cap.tick();
                std::thread::sleep(Duration::from_millis(10));
            }
        };
        spin(warmup, &mut cap);
        measuring.store(true, Ordering::Relaxed);
        let t0 = Instant::now();
        spin(measure, &mut cap);
        measuring.store(false, Ordering::Relaxed);
        let span = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        for t in threads {
            let (s, _r) = t.join().expect("client thread");
            offered_total += s;
        }
        let qps = measured.load(Ordering::Relaxed) as f64 / span.as_secs_f64();
        let w = frontend.window();
        g_qps.set(qps);
        g_p999.set(w.p999_ms);
        cap.sample();
        println!("{clients:>8} {qps:>12.0} {:>12.3} {:>12.3}", w.p99_ms, w.p999_ms);
        best_qps = best_qps.max(qps);
    }

    cap.emit("throughput");
    let result = frontend.shutdown()?;
    println!(
        "\nmax sustained qps/shard: {best_qps:.0}  (offered {offered_total}, \
         session resolved {}, rejected {})",
        result.metrics.total(),
        result.rejected
    );
    assert!(
        result.metrics.total() + result.rejected >= offered_total,
        "conservation: every offered query must resolve or be rejected \
         (offered {offered_total}, resolved {}, rejected {})",
        result.metrics.total(),
        result.rejected
    );
    Ok(())
}

//! Table 1: the toy coded-computation example — linear F decodes exactly
//! under the addition code; non-linear F is off by the cross term.

use parm::experiments::table1;

fn main() {
    println!("=== Table 1: parity P = X1 + X2, X1=3, X2=4 ===");
    println!(
        "{:<12} {:>10} {:>12} {:>18}",
        "F", "F(P)", "desired", "naive decode err"
    );
    for r in table1::rows(3.0, 4.0) {
        println!(
            "{:<12} {:>10.2} {:>12.2} {:>18.2}",
            r.f_name, r.f_p, r.desired, r.naive_decode_err
        );
    }
    // Sweep a grid to show the error is exactly the 2*x1*x2 cross term.
    let mut max_linear_err = 0.0f64;
    let mut max_cross_gap = 0.0f64;
    for i in -5..=5 {
        for j in -5..=5 {
            let (x1, x2) = (i as f64 * 0.7, j as f64 * 1.3);
            let rows = table1::rows(x1, x2);
            max_linear_err = max_linear_err.max(rows[0].naive_decode_err);
            max_cross_gap =
                max_cross_gap.max((rows[1].naive_decode_err - (2.0 * x1 * x2).abs()).abs());
        }
    }
    println!("\nmax linear decode error over grid: {max_linear_err:.2e} (exact)");
    println!("max |square error - 2*x1*x2| over grid: {max_cross_gap:.2e} (the cross term)");
}

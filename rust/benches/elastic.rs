//! Elastic vs. static fleet through the same whole-shard fault: does
//! runtime reconfiguration cost anything on the data path?
//!
//! Both fleets are cross-shard coding tiers serving the same paced
//! multi-client workload from the same seed. The *elastic* run drives
//! the control plane through the full lifecycle mid-run — scale out to
//! shards+1 (parity pool re-provisions toward ceil(shards*m/k) while
//! serving), ride a whole-shard kill of an original shard, then drain
//! and retire the added shard — while the *static* run keeps its
//! initial fleet and absorbs the identical kill.
//!
//! Emits `bench_out/elastic.json`: per scheme, resolved / reconstructed
//! / defaulted counts, recovery rate, and p50/p99/p99.9 latency, plus
//! the elastic run's event timeline (each reconfiguration step with the
//! rolling-window p99 observed at that moment). The timeline rows come
//! off the fleet's metric registry via [`parm::telemetry::series`] —
//! the same `parm_fleet_window_*` / `parm_shards` / parity-pool gauges
//! an operator scrapes — and the continuous series (periodic samples
//! plus the marked reconfiguration events) additionally lands in
//! `bench_out/elastic_timeseries.json`. Asserts conservation — every
//! offered query is accounted for in both schemes — and that the
//! elastic fleet's parity pool tracked its target through both resizes.
//!
//! Env knobs: PARM_BENCH_QUERIES (default 1600).

use std::sync::Arc;
use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::control::{ControlPlane, Fleet, FleetRunResult};
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedClient};
use parm::experiments::latency;
use parm::telemetry::series::Capture;
use parm::util::json::Json;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

const SHARDS: usize = 3;
const M: usize = 2;
const K: usize = 2;
const R_MAX: usize = 2;
const CLIENTS: usize = 8;
const SEED: u64 = 0xE1B3;
const VICTIM: usize = 1; // an original shard — the elastic margin must outlive it

struct Row {
    scheme: &'static str,
    resolved: u64,
    reconstructed: u64,
    defaulted: u64,
    rejected: u64,
    recovery_rate: f64,
    parity_overhead: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    events: Vec<Json>,
}

impl Row {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme)
            .set("resolved", self.resolved as usize)
            .set("reconstructed", self.reconstructed as usize)
            .set("defaulted", self.defaulted as usize)
            .set("rejected", self.rejected as usize)
            .set("recovery_rate", self.recovery_rate)
            .set("parity_overhead", self.parity_overhead)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("p999_ms", self.p999_ms)
            .set("events", Json::Arr(self.events.clone()))
    }
}

fn pool_for(shards: usize) -> usize {
    ((shards * M + K - 1) / K).max(1)
}

/// Parity-pool re-provisioning is generational and asynchronous; block
/// until size and target agree on `want`.
fn wait_pool(plane: &ControlPlane, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let size = plane.parity_pool_size().ok().flatten();
        let target = plane.parity_pool_target().ok().flatten();
        if size == Some(want) && target == Some(want) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "parity pool never reached {want} (size {size:?} target {target:?})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Paced Poisson clients; returns once every accepted query resolved.
fn drive(clients: Vec<ShardedClient>, queries: &[parm::tensor::Tensor], per: u64, per_rate: f64) {
    let mut joins = Vec::new();
    for (c, client) in clients.into_iter().enumerate() {
        let queries = queries.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(SEED ^ 0xE7A ^ (c as u64) << 9);
            let mut due = Instant::now();
            let mut accepted = 0u64;
            for i in 0..per {
                due += Duration::from_secs_f64(rng.exponential(per_rate));
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if client.submit(queries[i as usize % queries.len()].clone()).is_ok() {
                    accepted += 1;
                }
                let _ = client.poll();
            }
            while client.stats().resolved < accepted {
                if client.next(Duration::from_secs(8)).is_none() {
                    break;
                }
            }
        }));
    }
    for j in joins {
        let _ = j.join();
    }
}

fn service_config() -> ServiceConfig {
    let mut cfg = ServiceConfig::defaults(
        Mode::CrossShard {
            k: K,
            r_min: 1,
            r_max: R_MAX,
            halflife: Duration::from_millis(300),
        },
        &GPU,
    );
    cfg.m = M;
    cfg.shuffles = 0;
    cfg.seed = SEED;
    cfg.slo = Some(Duration::from_millis(1500));
    cfg
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_600);

    let models = latency::load_models(&m, 1, K, R_MAX, false)?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let rate = 320.0;
    let per = n / CLIENTS as u64;
    let per_rate = rate / CLIENTS as f64;
    let run_secs = per as f64 / per_rate;
    let scale_out_at = Duration::from_secs_f64(run_secs * 0.25);
    let kill_at = Duration::from_secs_f64(run_secs * 0.45);
    let scale_in_at = Duration::from_secs_f64(run_secs * 0.70);
    let spec = ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None };

    println!(
        "elastic sweep: {n} queries, {CLIENTS} clients, {SHARDS} shards (m={M}), \
         shard {VICTIM} dies whole at t={:.1}s of {run_secs:.1}s",
        kill_at.as_secs_f64()
    );
    println!(
        "elastic timeline: add-shard t={:.1}s, drain+remove t={:.1}s",
        scale_out_at.as_secs_f64(),
        scale_in_at.as_secs_f64()
    );
    println!(
        "{:<9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10} {:>9} {:>9} {:>10}",
        "scheme", "resolved", "recon", "default", "rejected", "recovery", "overhead", "p50(ms)", "p99(ms)", "p99.9(ms)"
    );

    let mut rows: Vec<Row> = Vec::new();

    // --- elastic: scale out -> whole-shard kill -> scale in ---
    {
        let tier = CrossShardFrontend::start(service_config(), spec, &models, &source.queries[0])?;
        let plane = Arc::new(ControlPlane::new(Fleet::CrossShard(tier)));
        let clients: Vec<ShardedClient> =
            (0..CLIENTS).map(|_| plane.client().expect("fleet is live")).collect();
        let start = Instant::now();
        let timeline = {
            let plane = Arc::clone(&plane);
            // The sampler folds fleet state into the registry on every
            // Capture sample — the bench timeline reads the exact gauges
            // a concurrent /metrics scrape would see.
            let registry = plane.registry();
            let sampler = plane.register_sampler();
            std::thread::spawn(move || {
                let mut cap = Capture::fleet(&registry, Duration::from_millis(250))
                    .with_extra_labels("live", "parm_shards", &[("state", "live")])
                    .with_extra("parity_pool", "parm_parity_pool_size");
                let sleep_until = |cap: &mut Capture, at: Duration| {
                    while start.elapsed() < at {
                        let left = at - start.elapsed();
                        std::thread::sleep(left.min(Duration::from_millis(50)));
                        cap.tick();
                    }
                };

                sleep_until(&mut cap, scale_out_at);
                let added = plane.add_shard().expect("scale out");
                assert_eq!(added, SHARDS, "append-only shard indices");
                wait_pool(&plane, pool_for(SHARDS + 1));
                cap.mark("scale-out");

                sleep_until(&mut cap, kill_at);
                for i in 0..M {
                    plane.kill_instance(VICTIM, i).expect("fleet is live");
                }
                cap.mark("kill-shard");

                sleep_until(&mut cap, scale_in_at);
                assert!(plane.drain(added).expect("drain the elastic margin"));
                plane.remove_shard(added).expect("retire the elastic margin");
                wait_pool(&plane, pool_for(SHARDS));
                cap.mark("scale-in");
                registry.drop_sampler(sampler);
                cap
            })
        };
        drive(clients, &source.queries, per, per_rate);
        let series = timeline.join().expect("timeline thread");
        series.emit("elastic_timeseries");
        let events: Vec<Json> = series
            .rows()
            .iter()
            .filter(|r| r.at(&["event"]).as_str().is_some())
            .cloned()
            .collect();
        plane.flush_open_groups()?;
        assert_eq!(plane.shards()?, SHARDS + 1, "retired slot keeps its index");
        assert_eq!(plane.provisioned_shards()?, SHARDS, "back to the initial footprint");
        let res = match plane.shutdown()? {
            FleetRunResult::CrossShard(res) => res,
            FleetRunResult::Sharded(_) => unreachable!("plane owns a cross-shard fleet"),
        };
        assert_eq!(
            res.fleet.per_shard.len(),
            SHARDS + 1,
            "the retired shard still reports its run record"
        );
        let t = &res.telemetry;
        let overhead = if t.groups_sealed > 0 {
            t.parity_jobs as f64 / t.groups_sealed as f64
        } else {
            0.0
        };
        let mut metrics = res.fleet.merged.metrics;
        assert_eq!(metrics.offered(), n, "elastic run conserves every offered query");
        rows.push(Row {
            scheme: "elastic",
            resolved: metrics.total(),
            reconstructed: metrics.reconstructed,
            defaulted: metrics.defaulted,
            rejected: metrics.rejected,
            recovery_rate: recovery(metrics.reconstructed, metrics.defaulted),
            parity_overhead: overhead,
            p50_ms: metrics.latency.median(),
            p99_ms: metrics.latency.p99(),
            p999_ms: metrics.latency.p999(),
            events,
        });
        print_row(rows.last().unwrap());
    }

    // --- static baseline: same fleet, same kill, no reconfiguration ---
    {
        let tier = CrossShardFrontend::start(service_config(), spec, &models, &source.queries[0])?;
        let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
        let killer = {
            let plan = tier.fault_plan(VICTIM);
            std::thread::spawn(move || {
                std::thread::sleep(kill_at);
                for i in 0..M {
                    plan.kill(i);
                }
            })
        };
        drive(clients, &source.queries, per, per_rate);
        let _ = killer.join();
        tier.flush_open_groups();
        let res = tier.shutdown()?;
        let t = &res.telemetry;
        let overhead = if t.groups_sealed > 0 {
            t.parity_jobs as f64 / t.groups_sealed as f64
        } else {
            0.0
        };
        let mut metrics = res.fleet.merged.metrics;
        assert_eq!(metrics.offered(), n, "static run conserves every offered query");
        rows.push(Row {
            scheme: "static",
            resolved: metrics.total(),
            reconstructed: metrics.reconstructed,
            defaulted: metrics.defaulted,
            rejected: metrics.rejected,
            recovery_rate: recovery(metrics.reconstructed, metrics.defaulted),
            parity_overhead: overhead,
            p50_ms: metrics.latency.median(),
            p99_ms: metrics.latency.p99(),
            p999_ms: metrics.latency.p999(),
            events: Vec::new(),
        });
        print_row(rows.last().unwrap());
    }

    let json = Json::Arr(rows.iter().map(Row::to_json).collect());
    let _ = std::fs::create_dir_all("bench_out");
    let path = "bench_out/elastic.json";
    if std::fs::write(path, json.to_string()).is_ok() {
        println!("(wrote {path})");
    }

    // Headline: reconfiguration is invisible to correctness. Both runs
    // account for every query; the elastic run additionally resized its
    // parity pool twice (checked inline) and retired a shard mid-run.
    let elastic = &rows[0];
    let fixed = &rows[1];
    assert!(
        elastic.reconstructed > 0,
        "the whole-shard kill must exercise cross-shard decode in the elastic run"
    );
    assert!(
        fixed.reconstructed > 0,
        "the whole-shard kill must exercise cross-shard decode in the static run"
    );
    println!(
        "elastic: recovery {:.3} p99 {:.3}ms p99.9 {:.3}ms vs static: recovery {:.3} \
         p99 {:.3}ms p99.9 {:.3}ms",
        elastic.recovery_rate,
        elastic.p99_ms,
        elastic.p999_ms,
        fixed.recovery_rate,
        fixed.p99_ms,
        fixed.p999_ms
    );
    println!("ok: scale-out -> whole-shard kill -> scale-in conserved every offered query");
    Ok(())
}

/// Of the queries that lost their own prediction, the fraction decode
/// brought back (1.0 when nothing was lost at all).
fn recovery(reconstructed: u64, defaulted: u64) -> f64 {
    let lost = reconstructed + defaulted;
    if lost == 0 {
        return 1.0;
    }
    reconstructed as f64 / lost as f64
}

fn print_row(r: &Row) {
    println!(
        "{:<9} {:>9} {:>9} {:>9} {:>9} {:>9.3} {:>10.3} {:>9.3} {:>9.3} {:>10.3}",
        r.scheme,
        r.resolved,
        r.reconstructed,
        r.defaulted,
        r.rejected,
        r.recovery_rate,
        r.parity_overhead,
        r.p50_ms,
        r.p99_ms,
        r.p999_ms,
    );
}

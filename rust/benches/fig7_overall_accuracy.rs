//! Figure 7: overall accuracy A_o (Eq. 1) on the CIFAR-10 stand-in as the
//! unavailability fraction f_u sweeps 0..0.2, for ParM k=2,3,4 vs the
//! default-prediction baseline; horizontal reference is A_a.

use parm::artifacts::Manifest;
use parm::experiments::accuracy;
use parm::util::json::Json;

const DATASET: &str = "synthvision10";
const ARCH: &str = "microresnet";

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let dep = m.deployed(DATASET, ARCH)?;

    let f_us: Vec<f64> = (0..=10).map(|i| i as f64 * 0.02).collect();
    println!("=== Figure 7: overall accuracy A_o vs f_u ({DATASET}/{ARCH}) ===");
    print!("{:<10}", "f_u");
    for f in &f_us {
        print!(" {f:>7.2}");
    }
    println!();

    let mut out = Vec::new();
    let mut reference_aa = None;
    for k in [2usize, 3, 4] {
        let par = m.parity(DATASET, ARCH, k, "sum", 0)?;
        let r = accuracy::evaluate(&m, dep, par, 7)?;
        reference_aa.get_or_insert(r.available);
        print!("{:<10}", format!("parm k={k}"));
        let series: Vec<f64> = f_us.iter().map(|&f| r.overall(f)).collect();
        for v in &series {
            print!(" {v:>7.3}");
        }
        println!();
        if k == 2 {
            print!("{:<10}", "default");
            for &f in &f_us {
                print!(" {:>7.3}", r.overall_default(f));
            }
            println!();
            out.push(Json::obj().set("series", "default").set(
                "values",
                f_us.iter().map(|&f| r.overall_default(f)).collect::<Vec<_>>(),
            ));
        }
        out.push(Json::obj().set("series", format!("parm_k{k}")).set("values", series));
    }
    println!("A_a (horizontal reference) = {:.3}", reference_aa.unwrap());
    out.push(Json::obj().set("series", "A_a").set("values", vec![reference_aa.unwrap()]));

    std::fs::create_dir_all("bench_out")?;
    std::fs::write("bench_out/fig7_overall.json", Json::Arr(out).to_string())?;
    println!("(wrote bench_out/fig7_overall.json)");
    Ok(())
}

//! Figure 11: median and 99.9th-percentile latency of ParM (k=2) vs the
//! Equal-Resources baseline across query rates, on both the GPU-profile
//! and CPU-profile clusters, under 4 background shuffles.
//!
//! Query rates are expressed as utilization of the no-redundancy system
//! and converted via the measured service time, so the sweep lands at the
//! same operating points as the paper regardless of host speed.
//!
//! Besides the end-of-run aggregate rows, this bench also emits a
//! **time series** (via the shared `run_fault_timeseries` scaffold): the
//! live windowed p50/p99/p99.9 sampled periodically through a run in
//! which one deployed instance is killed mid-way — the tail spikes at
//! the fault and, under ParM, settles back as parity reconstructions
//! absorb the dead instance's queries (emitted to
//! `bench_out/fig11_timeseries.json` for Figure 11-style timeline plots).
//!
//! Env knobs: PARM_BENCH_QUERIES (default 12000), PARM_BENCH_UTILS,
//! PARM_BENCH_TS_QUERIES (default 6000), PARM_BENCH_TS_SAMPLE_MS (250).

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::experiments::latency;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = env_u64("PARM_BENCH_QUERIES", 12_000);
    let utils: Vec<f64> = std::env::var("PARM_BENCH_UTILS")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0.3, 0.42, 0.55]);

    let mut rows = Vec::new();
    for profile in [&hardware::GPU, &hardware::CPU] {
        rows.extend(latency::parm_vs_equal_resources(
            &m, profile, 2, 1, n, &utils, 4, false, 0xF16_11,
        )?);
    }
    latency::emit("fig11_latency", &rows);

    // Paper shape check: at matching rates ParM's p99.9 should sit well
    // below Equal-Resources' while medians stay comparable.
    for pair in rows.chunks(2) {
        if let [parm, er] = pair {
            let gap_parm = parm.p999_ms - parm.median_ms;
            let gap_er = er.p999_ms - er.median_ms;
            println!(
                "util {:.2} [{}]: tail-gap parm={:.2}ms er={:.2}ms ({}x closer)",
                parm.utilization,
                parm.label,
                gap_parm,
                gap_er,
                if gap_parm > 0.0 { gap_er / gap_parm } else { f64::NAN }
            );
        }
    }

    // Time series across a fault event (default shuffle load).
    latency::run_fault_timeseries(
        &m, "fig11_timeseries", "parm-fault", 0.42, 4, false, 0xF16_11,
    )?;
    Ok(())
}

//! Figure 11: median and 99.9th-percentile latency of ParM (k=2) vs the
//! Equal-Resources baseline across query rates, on both the GPU-profile
//! and CPU-profile clusters, under 4 background shuffles.
//!
//! Query rates are expressed as utilization of the no-redundancy system
//! and converted via the measured service time, so the sweep lands at the
//! same operating points as the paper regardless of host speed.
//! Env knobs: PARM_BENCH_QUERIES (default 12000), PARM_BENCH_UTILS.

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::experiments::latency;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let utils: Vec<f64> = std::env::var("PARM_BENCH_UTILS")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0.3, 0.42, 0.55]);

    let mut rows = Vec::new();
    for profile in [&hardware::GPU, &hardware::CPU] {
        rows.extend(latency::parm_vs_equal_resources(
            &m, profile, 2, 1, n, &utils, 4, false, 0xF16_11,
        )?);
    }
    latency::emit("fig11_latency", &rows);

    // Paper shape check: at matching rates ParM's p99.9 should sit well
    // below Equal-Resources' while medians stay comparable.
    for pair in rows.chunks(2) {
        if let [parm, er] = pair {
            let gap_parm = parm.p999_ms - parm.median_ms;
            let gap_er = er.p999_ms - er.median_ms;
            println!(
                "util {:.2} [{}]: tail-gap parm={:.2}ms er={:.2}ms ({}x closer)",
                parm.utilization,
                parm.label,
                gap_parm,
                gap_er,
                if gap_parm > 0.0 { gap_er / gap_parm } else { f64::NAN }
            );
        }
    }
    Ok(())
}

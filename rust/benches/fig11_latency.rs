//! Figure 11: median and 99.9th-percentile latency of ParM (k=2) vs the
//! Equal-Resources baseline across query rates, on both the GPU-profile
//! and CPU-profile clusters, under 4 background shuffles.
//!
//! Query rates are expressed as utilization of the no-redundancy system
//! and converted via the measured service time, so the sweep lands at the
//! same operating points as the paper regardless of host speed.
//!
//! Besides the end-of-run aggregate rows, this bench also emits a
//! **time series**: the live windowed p50/p99/p99.9 sampled periodically
//! through a run in which one deployed instance is killed mid-way — the
//! tail spikes at the fault and, under ParM, settles back as parity
//! reconstructions absorb the dead instance's queries (emitted to
//! `bench_out/fig11_timeseries.json` for Figure 11-style timeline plots).
//!
//! Env knobs: PARM_BENCH_QUERIES (default 12000), PARM_BENCH_UTILS,
//! PARM_BENCH_TS_QUERIES (default 6000), PARM_BENCH_TS_SAMPLE_MS (250).

use std::time::Duration;

use parm::artifacts::Manifest;
use parm::cluster::hardware;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::experiments::latency;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = env_u64("PARM_BENCH_QUERIES", 12_000);
    let utils: Vec<f64> = std::env::var("PARM_BENCH_UTILS")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0.3, 0.42, 0.55]);

    let mut rows = Vec::new();
    for profile in [&hardware::GPU, &hardware::CPU] {
        rows.extend(latency::parm_vs_equal_resources(
            &m, profile, 2, 1, n, &utils, 4, false, 0xF16_11,
        )?);
    }
    latency::emit("fig11_latency", &rows);

    // Paper shape check: at matching rates ParM's p99.9 should sit well
    // below Equal-Resources' while medians stay comparable.
    for pair in rows.chunks(2) {
        if let [parm, er] = pair {
            let gap_parm = parm.p999_ms - parm.median_ms;
            let gap_er = er.p999_ms - er.median_ms;
            println!(
                "util {:.2} [{}]: tail-gap parm={:.2}ms er={:.2}ms ({}x closer)",
                parm.utilization,
                parm.label,
                gap_parm,
                gap_er,
                if gap_parm > 0.0 { gap_er / gap_parm } else { f64::NAN }
            );
        }
    }

    // ---- time series across a fault event ----
    let ts_n = env_u64("PARM_BENCH_TS_QUERIES", 6_000);
    let sample = Duration::from_millis(env_u64("PARM_BENCH_TS_SAMPLE_MS", 250).max(1));
    let models = latency::load_models(&m, 1, 2, 1, false)?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = parm::workload::QuerySource::from_dataset(&m, ds)?;
    let probe = source.queries[0].clone();
    let mean = parm::coordinator::service::measure_service(&models.deployed, &probe, 20);
    let profile = &hardware::GPU;
    let rate = 0.42 * profile.default_m as f64 / (mean.as_secs_f64() * profile.exec_scale.max(1.0));

    let mut cfg = ServiceConfig::defaults(
        Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
        profile,
    );
    cfg.seed = 0xF16_11;
    cfg.slo = Some(Duration::from_secs(2)); // backstop for doubly-lost groups
    // A short window makes the timeline responsive: each sample reflects
    // roughly the last second of traffic, so the fault transient shows as
    // a spike instead of being averaged away.
    cfg.metrics_window = Duration::from_secs(1);
    // Kill one deployed instance ~40% of the way through the run.
    let kill_at = Duration::from_secs_f64(0.4 * ts_n as f64 / rate);
    cfg.fault_schedule = vec![(0, kill_at, Duration::ZERO)];
    println!(
        "\ntime series: {ts_n} queries at {rate:.0} qps, instance 0 dies at t={:.1}s",
        kill_at.as_secs_f64()
    );
    let (row, series) =
        latency::run_point_timeseries(&cfg, &models, &source, ts_n, rate, "parm-fault", sample)?;
    latency::emit_timeseries("fig11_timeseries", &series);
    println!("aggregate: {}", row.line());
    Ok(())
}

//! Adaptive redundancy under a straggler burst — the rateless scheme's
//! predictor watching a fault arrive and clear. A paced Poisson client
//! drives one serving session in `mode: rateless` (k=2, r in [1, 2]);
//! mid-run, *two* of the four deployed instances fail for a window (the
//! undetected-zombie model of §5.1, twice over, so coding groups can
//! lose both slots — beyond what fixed-r ParM could ever reconstruct).
//! The periodic log shows the live windowed tail next to the scheme's
//! telemetry: the unavailability estimate jumps when losses appear, the
//! per-group parity count `r` ramps from the floor to the ceiling, and
//! after the burst clears both decay back — redundancy priced to the
//! cluster's actual health, not provisioned for the worst case.
//!
//! The run is also followed through the session's metric registry
//! ([`parm::telemetry`]): a [`Capture`] samples the same
//! `parm_session_window_*` and `parm_scheme_*` gauges an operator
//! would scrape off `--metrics-addr`, and at the end the scrape-side
//! view must agree with the in-process one — the ramp to the ceiling
//! is visible on both pipes.
//!
//! Run with: `cargo run --release --example adaptive_serve`
//! Knobs: PARM_QUERIES (default 1500), PARM_HALFLIFE_MS (default 250).

use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::telemetry::series::Capture;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let n = env_or("PARM_QUERIES", 1500).max(200);
    let halflife = Duration::from_millis(env_or("PARM_HALFLIFE_MS", 250).max(50));
    let (k, r_min, r_max, m_inst) = (2usize, 1usize, 2usize, 4usize);

    let manifest = Manifest::load_default()?;
    let ds = manifest.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&manifest, ds)?;
    let models = latency::load_models(&manifest, 1, k, r_max, false)?;

    let mut cfg = ServiceConfig::defaults(
        Mode::Rateless { k, r_min, r_max, halflife },
        &GPU,
    );
    cfg.m = m_inst;
    cfg.shuffles = 1;
    cfg.seed = 0xADAB;
    cfg.slo = Some(Duration::from_millis(1500)); // unrecoverable queries default
    cfg.metrics_window = Duration::from_secs(1); // responsive live tail

    // Pace so the run lasts >= 4 s (several predictor half-lives on each
    // side of the burst) without exceeding ~40% of modeled capacity.
    let probe = source.queries[0].clone();
    let measured = parm::coordinator::service::measure_service(&models.deployed, &probe, 20);
    let mean = measured.as_secs_f64() * GPU.exec_scale.max(1.0);
    let rate = (0.4 * m_inst as f64 / mean).min(n as f64 / 4.0);
    let run_secs = n as f64 / rate;
    let burst_at = Duration::from_secs_f64(run_secs * 0.35);
    let burst_len = Duration::from_secs_f64(run_secs * 0.30);
    // Instances 0 and 1 fail together: a two-deep straggler burst.
    cfg.fault_schedule = vec![(0, burst_at, burst_len), (1, burst_at, burst_len)];
    let mut handle = ServiceBuilder::new(cfg).build(&models, &source.queries[0])?;
    // Shadow the live log with the operator's view: the same gauges a
    // `/metrics` scrape serves, sampled off the session's registry.
    let registry = handle.registry();
    let mut cap = Capture::session(&registry, Duration::from_millis(200))
        .with_extra("r", "parm_scheme_last_r")
        .with_extra("unavailability", "parm_scheme_unavailability");

    println!(
        "{n} queries at {rate:.0} qps over ~{run_secs:.1}s; instances 0+1 fail at \
         t={:.1}s for {:.1}s (predictor half-life {halflife:?})\n",
        burst_at.as_secs_f64(),
        burst_len.as_secs_f64()
    );
    println!(
        "{:>7} {:>9} {:>9} {:>9} {:>6} {:>9} {:>10}",
        "t(s)", "resolved", "p99(ms)", "recovery", "r", "unavail", "overhead"
    );

    let start = Instant::now();
    let mut rng = Pcg64::new(0x5EED);
    let mut due = start;
    let sample_every = Duration::from_millis(200);
    let mut next_sample = start + sample_every;
    let mut max_r_seen = 0usize;
    for i in 0..n {
        due += Duration::from_secs_f64(rng.exponential(rate));
        loop {
            let _ = handle.poll();
            let now = Instant::now();
            if now >= next_sample {
                let w = handle.window_snapshot();
                let t = handle.scheme_telemetry().expect("rateless exposes telemetry");
                max_r_seen = max_r_seen.max(t.last_r);
                let overhead = if t.groups_sealed > 0 {
                    t.parity_jobs as f64 / t.groups_sealed as f64
                } else {
                    0.0
                };
                println!(
                    "{:>7.1} {:>9} {:>9.2} {:>9.3} {:>6} {:>9.3} {:>10.3}",
                    now.duration_since(start).as_secs_f64(),
                    w.resolved,
                    w.p99_ms,
                    w.recovery_rate,
                    t.last_r,
                    t.unavailability,
                    overhead,
                );
                cap.sample();
                next_sample += sample_every;
            }
            if now >= due {
                break;
            }
            let wake = due.min(next_sample);
            let now = Instant::now();
            if wake > now {
                std::thread::sleep((wake - now).min(Duration::from_millis(2)));
            }
        }
        handle.submit(source.queries[(i as usize) % source.queries.len()].clone());
    }
    let _ = handle.drain();
    handle.publish_telemetry();
    cap.sample();
    let final_t = handle.scheme_telemetry().expect("telemetry");
    let r_after_decay = final_t.last_r;
    let res = handle.shutdown();

    let mut metrics = res.metrics;
    println!("\n{}", metrics.report("run total"));
    println!(
        "wall={:.1}s reconstructions={} dropped_jobs={} parity_overhead={:.3}",
        res.wall.as_secs_f64(),
        res.reconstructions,
        res.dropped_jobs,
        final_t.parity_jobs as f64 / final_t.groups_sealed.max(1) as f64,
    );

    assert!(
        max_r_seen >= r_max,
        "the straggler burst must ramp r to the ceiling (max seen {max_r_seen})"
    );
    println!("✓ r ramped to {max_r_seen} during the burst");
    // The registry watched the same burst: the gauges fold in on the
    // session pump's cadence, so the scrape-side timeline sees the
    // ramp too (the burst spans many fold intervals).
    let scraped_r = cap
        .rows()
        .iter()
        .filter_map(|row| row.at(&["r"]).as_f64())
        .fold(0.0_f64, f64::max);
    assert!(
        scraped_r as usize >= r_max,
        "the registry's parm_scheme_last_r must show the ramp (max {scraped_r})"
    );
    println!("✓ the metric registry saw the same ramp (parm_scheme_last_r peaked at {scraped_r})");
    if r_after_decay == r_min {
        println!("✓ r decayed back to the floor after the burst cleared");
    } else {
        println!(
            "! r still at {r_after_decay} at the last sample (tail too short for \
             full decay on this host)"
        );
    }
    if res.reconstructions > 0 {
        println!("✓ {} predictions recovered by parity decode", res.reconstructions);
    }
    Ok(())
}

//! Object localization with ParM.
//!
//! Paper scenario: §4.2.1 / Figure 8 — the regression task that shows
//! parity models generalize beyond classification. A bounding-box
//! regressor has no "default prediction" worth returning, so
//! reconstruction is the only viable fallback when an instance is
//! unavailable; the measure of degraded quality is IoU against the
//! deployed model's own boxes rather than top-1 accuracy. Prints
//! per-example boxes plus the aggregate IoU of deployed predictions vs
//! ParM reconstructions.
//!
//! Run with: `cargo run --release --example object_localization`

use parm::artifacts::Manifest;
use parm::coordinator::{decoder, encoder::Encoder};
use parm::experiments::accuracy::{self, run_all};
use parm::runtime::engine::Executable;
use parm::workload::QuerySource;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let dep_entry = m.deployed("synthloc", "microresnet")?;
    let par_entry = m.parity("synthloc", "microresnet", 2, "sum", 0)?;
    let batch = *dep_entry.files.keys().max().unwrap();
    let deployed = Executable::load(
        m.hlo_path(dep_entry, batch)?, &dep_entry.name, &dep_entry.input_shape,
        batch, dep_entry.out_dim,
    )?;
    let parity = Executable::load(
        m.hlo_path(par_entry, batch)?, &par_entry.name, &par_entry.input_shape,
        batch, par_entry.out_dim,
    )?;

    let ds = m.dataset("synthloc")?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let n = (source.len() / 2) * 2;
    let outs = run_all(&deployed, &source.queries[..n])?;

    let enc = Encoder::sum(2);
    let mut iou_dep = 0.0f64;
    let mut iou_rec = 0.0f64;
    for s in 0..n / 2 {
        let (a, b) = (2 * s, 2 * s + 1);
        let p = enc.encode(&[&source.queries[a], &source.queries[b]])?;
        let fp = run_all(&parity, &[p])?.remove(0);
        // Each of the two "one slow instance" scenarios.
        for (miss, have) in [(a, b), (b, a)] {
            let rec = decoder::decode_r1(
                &[1.0, 1.0], &fp,
                &[
                    if miss == 2 * s { None } else { Some(outs[2 * s].clone()) },
                    if miss == 2 * s + 1 { None } else { Some(outs[2 * s + 1].clone()) },
                ],
                miss - 2 * s,
            )?;
            let truth = source.box_of(miss).unwrap();
            iou_rec += accuracy::iou(rec.data(), &truth) as f64;
            iou_dep += accuracy::iou(outs[miss].data(), &truth) as f64;
            let _ = have;
            if s < 3 && miss == a {
                println!(
                    "example {s}: truth={:?}\n  deployed box      ={:?} (IoU {:.3})\n  reconstructed box ={:?} (IoU {:.3})",
                    truth,
                    &outs[miss].data()[..4],
                    accuracy::iou(outs[miss].data(), &truth),
                    &rec.data()[..4],
                    accuracy::iou(rec.data(), &truth),
                );
            }
        }
    }
    println!(
        "\nmean IoU over {} scenarios: deployed={:.3}, ParM reconstruction={:.3}",
        n,
        iou_dep / n as f64,
        iou_rec / n as f64
    );
    println!("(paper: 0.945 vs 0.674 on CUB-200 — reconstructions capture the gist)");
    Ok(())
}

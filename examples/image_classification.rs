//! Image classification with ParM (the paper's flagship workload):
//! full degraded-mode accuracy evaluation on the CIFAR-10 stand-in across
//! k = 2, 3, 4 and both encoders, printing the accuracy trade-off table.
//!
//! Paper scenario: §4.2 / Figures 6-7-9-10 — how much accuracy a
//! *reconstructed* prediction loses relative to the deployed model's own
//! output (A_d vs A_a), how that degrades as k grows, how the
//! task-specific concat encoder compares to the generic sum, and the
//! Eq. 1 overall accuracy A_o at the expected unavailability rate.
//!
//! Run with: `cargo run --release --example image_classification`

use parm::artifacts::Manifest;
use parm::experiments::accuracy;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let dataset = "synthvision10";
    let arch = "microresnet";
    let dep = m.deployed(dataset, arch)?;

    println!("ParM on {dataset}/{arch} — accuracy under unavailability\n");
    println!(
        "{:>4} {:>9} {:>8} {:>8} {:>9} {:>22}",
        "k", "encoder", "A_a", "A_d", "default", "A_o @ f_u=5% (Eq. 1)"
    );
    for (k, enc) in [(2, "sum"), (3, "sum"), (4, "sum"), (2, "concat"), (4, "concat")] {
        match m.parity(dataset, arch, k, enc, 0) {
            Ok(par) => {
                let r = accuracy::evaluate(&m, dep, par, 7)?;
                println!(
                    "{:>4} {:>9} {:>8.3} {:>8.3} {:>9.3} {:>22.3}",
                    k, enc, r.available, r.degraded, r.default_baseline,
                    r.overall(0.05)
                );
            }
            Err(_) => println!("{k:>4} {enc:>9}   (not in artifacts — rerun `make artifacts`)"),
        }
    }
    println!(
        "\nreading: A_d degrades as k grows (more queries per parity), the\n\
         task-specific concat encoder beats the generic sum, and at expected\n\
         unavailability (f_u <= 10%) overall accuracy stays near A_a — the\n\
         paper's Figure 7/9/10 story."
    );
    Ok(())
}

//! Multi-client serving under overload and failure — the paper's
//! deployment scenario (§2.1): a prediction-serving frontend takes
//! concurrent query streams from many users while the cluster misbehaves.
//! Eight (or `PARM_CLIENTS`) client threads drive three phases through
//! the multi-client frontend: (1) paced Poisson traffic against the
//! healthy cluster; (2) a synchronized overload burst, where admission
//! control (`RejectAbove`) sheds load at `submit` instead of letting the
//! pool backlog grow without bound; (3) paced traffic again, during which
//! one deployed instance is killed permanently (the undetected-zombie
//! failure model of §5.1) — ParM keeps answering the dead instance's
//! queries via parity reconstruction, with the SLO default as the
//! backstop. Prints per-client windowed p50/p99, recovery and reject
//! counts — the serving-system view of Figure 11's tail-latency story.
//!
//! Run with: `cargo run --release --example multi_client`
//! Knobs: PARM_CLIENTS (default 8), PARM_QUERIES_PER_CLIENT (default 150).

use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::AdmissionPolicy;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let clients = env_or("PARM_CLIENTS", 8).max(1) as usize;
    let per = env_or("PARM_QUERIES_PER_CLIENT", 150).max(20);

    let m = Manifest::load_default()?;
    let k = 2usize;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let models = latency::load_models(&m, 1, k, 1, false)?;

    // Phase split per client: 40% paced, 20% burst, 40% paced.
    let paced1 = per * 2 / 5;
    let burst = per / 5;
    let paced2 = per - paced1 - burst;
    let m_instances = 4usize;
    let rate = 160.0; // total qps, comfortably inside the simulated capacity
    let per_rate = rate / clients as f64;
    // The instance kill lands mid-way through phase 3.
    let kill_at = Duration::from_secs_f64(
        (paced1 as f64 / per_rate) + 0.5 + (paced2 as f64 / per_rate) * 0.4,
    );

    let mut cfg =
        ServiceConfig::defaults(Mode::Parm { k, encoders: vec![Encoder::sum(k)] }, &GPU);
    cfg.m = m_instances;
    cfg.shuffles = 1;
    cfg.seed = 0xC11E77;
    cfg.slo = Some(Duration::from_secs(2)); // backstop for doubly-lost groups
    // Low enough that even one client's burst alone overruns it — the
    // paced phases never get near it.
    cfg.admission = AdmissionPolicy::RejectAbove { backlog: 24 };
    cfg.metrics_window = Duration::from_secs(60); // cover the whole run
    cfg.fault_schedule = vec![(0, kill_at, Duration::ZERO)];

    println!(
        "{clients} clients x {per} queries (paced {paced1} + burst {burst} + paced {paced2}) \
         at {rate:.0} qps total, m={m_instances}, k={k}; instance 0 dies at t={:.1}s\n",
        kill_at.as_secs_f64()
    );

    let frontend = ServiceBuilder::new(cfg).serve(&models, &source.queries[0])?;
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = frontend.client();
        let queries = source.queries.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(0xFACADE ^ (c as u64) << 13);
            let mut due = Instant::now();
            let mut accepted = 0u64;
            for i in 0..per {
                let paced = i < paced1 || i >= paced1 + burst;
                if paced {
                    due += Duration::from_secs_f64(rng.exponential(per_rate));
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                } else if i == paced1 {
                    // Burst phase starts: submit as fast as possible and
                    // let admission control do its job.
                    due = Instant::now();
                }
                if client.submit(queries[i as usize % queries.len()].clone()).is_ok() {
                    accepted += 1;
                }
                let _ = client.poll();
                if !paced && i + 1 == paced1 + burst {
                    // Re-anchor pacing after the burst.
                    due = Instant::now();
                }
            }
            while client.stats().resolved < accepted {
                if client.next(Duration::from_secs(8)).is_none() {
                    break;
                }
            }
            client
        }));
    }

    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>10} {:>10} {:>10} {:>9}",
        "client", "submitted", "resolved", "rejected", "p50(ms)", "p99(ms)", "recovered",
        "default"
    );
    let (mut total_rejected, mut total_recovered) = (0u64, 0u64);
    for j in joins {
        let client = j.join().expect("client thread");
        let st = client.stats();
        let w = client.window();
        total_rejected += st.rejected;
        total_recovered += st.recovered;
        println!(
            "{:<8} {:>9} {:>9} {:>9} {:>10.3} {:>10.3} {:>10} {:>9}",
            client.id(),
            st.submitted,
            st.resolved,
            st.rejected,
            w.p50_ms,
            w.p99_ms,
            st.recovered,
            st.defaulted
        );
    }

    println!("\nfrontend window: {}", frontend.window().report("all-clients"));
    let res = frontend.shutdown()?;
    let mut metrics = res.metrics;
    println!("{}", metrics.report("run total"));
    println!(
        "wall={:.1}s reconstructions={} dropped_jobs={} rejected={}",
        res.wall.as_secs_f64(),
        res.reconstructions,
        res.dropped_jobs,
        res.rejected
    );
    if total_recovered > 0 {
        println!("\n✓ queries swallowed by the dead instance came back via redundancy");
    }
    if total_rejected > 0 {
        println!("✓ admission control shed load during the overload burst");
    }
    Ok(())
}

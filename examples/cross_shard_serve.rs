//! Surviving the loss of an entire shard — the correlated failure the
//! paper's erasure-coding framing is meant to absorb, which intra-shard
//! coding cannot: when a whole fault domain dies, its data queries *and*
//! their parity die together. Here every coding group stripes its k data
//! batches over k distinct shards and sends parities to a shared
//! cross-shard pool (`Mode::CrossShard`), so the mid-run kill of every
//! instance in one shard costs each group at most one slot — and each of
//! those decodes from the surviving slots plus the shared parity, at a
//! redundancy the fleet-level straggler predictor ramps as the fault's
//! losses are observed.
//!
//! Timeline: paced Poisson clients warm the fleet; one shard is killed
//! whole mid-run (undetected zombies — the router keeps sending its
//! clients there); the run finishes and the example reports per-client
//! stats, the per-shard unavailability estimates, parity overhead, and
//! the merged record — with the killed shard's queries resolved by
//! reconstruction, not SLO defaults.
//!
//! Run with: `cargo run --release --example cross_shard_serve`
//! Knobs: PARM_CLIENTS (default 12), PARM_QUERIES_PER_CLIENT (default
//! 80), PARM_SHARDS (default 4).

use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec};
use parm::experiments::latency;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let clients = env_or("PARM_CLIENTS", 12).max(2) as usize;
    let per = env_or("PARM_QUERIES_PER_CLIENT", 80).max(10);
    let shards = env_or("PARM_SHARDS", 4).max(2) as usize;
    let k = 2usize;
    let r_max = 2usize;

    let m = Manifest::load_default()?;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let models = latency::load_models(&m, 1, k, r_max, false)?;

    let rate = 240.0; // total qps, comfortably inside simulated capacity
    let per_rate = rate / clients as f64;
    let run_secs = per as f64 / per_rate;
    let kill_at = Duration::from_secs_f64(run_secs * 0.4);

    let mut cfg = ServiceConfig::defaults(
        Mode::CrossShard {
            k,
            r_min: 1,
            r_max,
            halflife: Duration::from_millis(400),
        },
        &GPU,
    );
    cfg.m = 2;
    cfg.shuffles = 1;
    cfg.seed = 0xC5055;
    cfg.slo = Some(Duration::from_secs(2)); // backstop; decode should beat it

    let tier = CrossShardFrontend::start(
        cfg,
        ShardSpec { shards, vnodes: 64, global_backlog: None },
        &models,
        &source.queries[0],
    )?;
    let victim = shards - 1;
    println!(
        "{clients} clients x {per} queries over {shards} shards at {rate:.0} qps; \
         coding groups stripe k={k} slots across shards, parity pools of {} instances; \
         shard {victim} dies WHOLE at t={:.1}s\n",
        tier.parity_pool_size(),
        kill_at.as_secs_f64()
    );

    let start = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = tier.client();
        let queries = source.queries.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(0xC5EED ^ (c as u64) << 11);
            let mut due = Instant::now();
            let mut accepted = 0u64;
            for i in 0..per {
                due += Duration::from_secs_f64(rng.exponential(per_rate));
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if client.submit(queries[i as usize % queries.len()].clone()).is_ok() {
                    accepted += 1;
                }
                let _ = client.poll(); // keep inboxes from growing
            }
            while client.stats().resolved < accepted {
                if client.next(Duration::from_secs(8)).is_none() {
                    break;
                }
            }
            client
        }));
    }

    // Chaos timeline: the whole shard dies mid-run.
    let now = start.elapsed();
    if kill_at > now {
        std::thread::sleep(kill_at - now);
    }
    tier.kill_shard(victim);
    println!(
        "t={:.1}s: killed EVERY instance of shard {victim} (undetected zombies; \
         its clients keep submitting there)",
        start.elapsed().as_secs_f64()
    );
    // Mid-run telemetry a beat later: the fleet predictor has seen the
    // losses and warmed r.
    std::thread::sleep(Duration::from_millis(800));
    let t = tier.telemetry();
    println!(
        "t={:.1}s: fleet unavailability={:.3} per-shard={:?} last_r={} recon={}\n",
        start.elapsed().as_secs_f64(),
        t.fleet_unavailability,
        t.per_shard_unavailability
            .iter()
            .map(|p| (p * 1e3).round() / 1e3)
            .collect::<Vec<_>>(),
        t.last_r,
        t.reconstructions
    );

    println!(
        "{:<8} {:>6} {:>9} {:>9} {:>10} {:>10} {:>10} {:>9}",
        "client", "shard", "submitted", "resolved", "p50(ms)", "p99(ms)", "recovered", "default"
    );
    let mut joined = Vec::new();
    for j in joins {
        joined.push(j.join().expect("client thread"));
    }
    // Tail groups get parity protection immediately.
    tier.flush_open_groups();
    let mut total_recovered = 0u64;
    let mut total_defaulted = 0u64;
    for client in &joined {
        let st = client.stats();
        let w = client.window();
        total_recovered += st.recovered;
        total_defaulted += st.defaulted;
        println!(
            "{:<8} {:>6} {:>9} {:>9} {:>10.3} {:>10.3} {:>10} {:>9}",
            client.id(),
            client.shard().map_or_else(|| "-".into(), |s| s.to_string()),
            st.submitted,
            st.resolved,
            w.p50_ms,
            w.p99_ms,
            st.recovered,
            st.defaulted,
        );
    }

    println!();
    for s in 0..tier.shards() {
        let tagline = if s == victim { " (killed whole)" } else { "" };
        println!("shard {s}{tagline}: {}", tier.shard_window(s).report("window"));
    }

    let res = tier.shutdown()?;
    let t = &res.telemetry;
    println!(
        "\ncoding: groups={} parity_jobs={} (overhead {:.3}) reconstructions={}",
        t.groups_sealed,
        t.parity_jobs,
        if t.groups_sealed > 0 { t.parity_jobs as f64 / t.groups_sealed as f64 } else { 0.0 },
        t.reconstructions
    );
    for (ri, r) in res.parity.iter().enumerate() {
        println!(
            "parity pool r{ri}: parity_queries={} dropped_jobs={}",
            r.metrics.total(),
            r.dropped_jobs
        );
    }
    let mut metrics = res.fleet.merged.metrics;
    println!("{}", metrics.report("fleet total"));
    let sum_resolved: u64 = res.fleet.per_shard.iter().map(|r| r.metrics.total()).sum();
    assert_eq!(metrics.total(), sum_resolved, "merged record equals per-shard sums");
    println!(
        "\n✓ whole-shard kill absorbed: {} cross-shard reconstructions, {} recovered \
         at clients, {} defaults",
        t.reconstructions, total_recovered, total_defaulted
    );
    if total_defaulted == 0 {
        println!("✓ zero queries lost to the SLO — every slot decoded or resolved natively");
    }
    Ok(())
}

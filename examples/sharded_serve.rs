//! Sharded serving under a shard-local failure — the paper's cluster
//! setting (§2.1, §6) scaled past one dispatcher: 16 (or `PARM_CLIENTS`)
//! client threads drive paced Poisson traffic into a 4-shard (or
//! `PARM_SHARDS`) tier, where each shard is a fully independent serving
//! session (own pools, dispatcher, fault domain) behind a
//! consistent-hash router. Mid-run, one shard is degraded in two acts:
//! first a deployed instance is killed (the undetected-zombie model of
//! §5.1 — the shard's parity model keeps answering via reconstruction
//! while the *other shards' latency profiles stay untouched*), then the
//! shard is drained from the ring, so its clients' subsequent submits
//! reroute to the surviving shards without losing a single in-flight
//! query. Prints per-client and per-shard stats, the merged fleet
//! window, and the merged run record whose totals equal the per-shard
//! sums.
//!
//! Run with: `cargo run --release --example sharded_serve`
//! Knobs: PARM_CLIENTS (default 16), PARM_QUERIES_PER_CLIENT (default
//! 100), PARM_SHARDS (default 4).

use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::AdmissionPolicy;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::shards::{ShardSpec, ShardedFrontend};
use parm::experiments::latency;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let clients = env_or("PARM_CLIENTS", 16).max(1) as usize;
    let per = env_or("PARM_QUERIES_PER_CLIENT", 100).max(10);
    let shards = env_or("PARM_SHARDS", 4).max(2) as usize;
    let degraded = shards - 1; // the shard we will kill and drain

    let m = Manifest::load_default()?;
    let k = 2usize;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let models = latency::load_models(&m, 1, k, 1, false)?;

    let rate = 240.0; // total qps, comfortably inside the simulated capacity
    let per_rate = rate / clients as f64;
    let run_secs = per as f64 / per_rate;
    let kill_at = Duration::from_secs_f64(run_secs * 0.35);
    let drain_at = Duration::from_secs_f64(run_secs * 0.6);

    let mut cfg =
        ServiceConfig::defaults(Mode::Parm { k, encoders: vec![Encoder::sum(k)] }, &GPU);
    cfg.m = 4;
    cfg.shuffles = 1;
    cfg.seed = 0x54A2D;
    cfg.slo = Some(Duration::from_secs(2)); // backstop for doubly-lost groups
    cfg.admission = AdmissionPolicy::RejectAbove { backlog: 32 };
    cfg.metrics_window = Duration::from_secs(60); // cover the whole run
    let spec = ShardSpec { shards, vnodes: 64, global_backlog: Some(32 * shards * 4) };

    println!(
        "{clients} clients x {per} queries over {shards} shards at {rate:.0} qps total; \
         shard {degraded}: instance 0 dies at t={:.1}s, drained from the ring at t={:.1}s\n",
        kill_at.as_secs_f64(),
        drain_at.as_secs_f64()
    );

    let tier = ShardedFrontend::start(cfg, spec, &models, &source.queries[0])?;
    let start = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = tier.client();
        let queries = source.queries.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(0x5EED5 ^ (c as u64) << 13);
            let mut due = Instant::now();
            let mut accepted = 0u64;
            for i in 0..per {
                due += Duration::from_secs_f64(rng.exponential(per_rate));
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if client.submit(queries[i as usize % queries.len()].clone()).is_ok() {
                    accepted += 1;
                }
                let _ = client.poll(); // keep inboxes from growing
            }
            while client.stats().resolved < accepted {
                if client.next(Duration::from_secs(8)).is_none() {
                    break;
                }
            }
            client
        }));
    }

    // Chaos timeline, driven from the main thread.
    let sleep_until = |at: Duration| {
        let now = start.elapsed();
        if at > now {
            std::thread::sleep(at - now);
        }
    };
    sleep_until(kill_at);
    tier.kill_instance(degraded, 0);
    println!(
        "t={:.1}s: killed shard {degraded} instance 0 (undetected zombie)",
        start.elapsed().as_secs_f64()
    );
    sleep_until(drain_at);
    tier.drain_shard(degraded).expect("drain the degraded shard");
    println!(
        "t={:.1}s: drained shard {degraded} from the ring ({} live shards remain)\n",
        start.elapsed().as_secs_f64(),
        tier.live_shards()
    );

    println!(
        "{:<8} {:>6} {:>9} {:>9} {:>9} {:>10} {:>10} {:>10}",
        "client", "shard", "submitted", "resolved", "rejected", "p50(ms)", "p99(ms)", "recovered"
    );
    let mut total_recovered = 0u64;
    for j in joins {
        let client = j.join().expect("client thread");
        let st = client.stats();
        let w = client.window();
        total_recovered += st.recovered;
        println!(
            "{:<8} {:>6} {:>9} {:>9} {:>9} {:>10.3} {:>10.3} {:>10}",
            client.id(),
            client.shard().map_or_else(|| "-".into(), |s| s.to_string()),
            st.submitted,
            st.resolved,
            st.rejected,
            w.p50_ms,
            w.p99_ms,
            st.recovered,
        );
    }

    println!();
    for s in 0..tier.shards() {
        let tagline = if s == degraded { " (degraded + drained)" } else { "" };
        println!("shard {s}{tagline}: {}", tier.shard_window(s).report("window"));
    }
    println!("fleet:  {}", tier.window().report("merged window"));

    let res = tier.shutdown()?;
    let mut metrics = res.merged.metrics;
    println!("\n{}", metrics.report("fleet total"));
    println!(
        "wall={:.1}s reconstructions={} dropped_jobs={} rejected={}",
        res.merged.wall.as_secs_f64(),
        res.merged.reconstructions,
        res.merged.dropped_jobs,
        res.merged.rejected
    );
    let sum_resolved: u64 = res.per_shard.iter().map(|r| r.metrics.total()).sum();
    assert_eq!(
        metrics.total(),
        sum_resolved,
        "merged resolved count equals the per-shard sums"
    );
    if total_recovered > 0 {
        println!("\n✓ the degraded shard kept answering via parity reconstruction");
    }
    println!("✓ rerouted submits after the drain; {sum_resolved} queries conserved fleet-wide");
    Ok(())
}

//! Elastic fleet, operated over the admin socket — the full lifecycle
//! the embedded control plane exists for: a cross-shard coding tier
//! serves paced clients while an "operator" (this process, speaking the
//! same line-oriented JSON protocol `parm admin` uses) scales the fleet
//! out, rides through the whole-shard kill the extra capacity was
//! bought for, and scales back in — all without pausing the data path
//! or losing an accepted query.
//!
//! Timeline (fractions of the run):
//!   t=0.25  `add-shard` over the socket; the shared parity pool
//!           re-provisions toward ceil(shards*m/k) while serving.
//!   t=0.50  every instance of shard 1 is killed (undetected zombies);
//!           coding groups decode from surviving slots + shared parity.
//!   t=0.75  `drain` + `remove-shard` retire the added shard; its
//!           ring points vanish, in-flight queries still resolve.
//!
//! Along the way the example prints raw admin replies (`status`,
//! `recommend`, `telemetry`) exactly as an operator would see them,
//! and scrapes its own Prometheus endpoint ([`parm::telemetry::Exporter`]
//! over the fleet's metric registry) mid-fault — the shard-state,
//! reconfiguration-verb, and merged-window families answer live while
//! the killed shard is being decoded around.
//!
//! Run with: `cargo run --release --example elastic_serve`
//! Knobs: PARM_CLIENTS (default 10), PARM_QUERIES_PER_CLIENT (default
//! 90), PARM_SHARDS (default 3).

#[cfg(not(unix))]
fn main() {
    eprintln!(
        "elastic_serve drives the control plane over a unix domain socket, \
         which this platform does not support"
    );
}

#[cfg(unix)]
fn main() -> anyhow::Result<()> {
    imp::run()
}

#[cfg(unix)]
mod imp {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::os::unix::net::UnixStream;
    use std::path::Path;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use parm::artifacts::Manifest;
    use parm::cluster::hardware::GPU;
    use parm::coordinator::control::{AdminServer, ControlPlane, Fleet, FleetRunResult};
    use parm::coordinator::service::{Mode, ServiceConfig};
    use parm::coordinator::shards::{CrossShardFrontend, ShardSpec};
    use parm::experiments::latency;
    use parm::telemetry::Exporter;
    use parm::util::json::Json;
    use parm::util::rng::Pcg64;
    use parm::workload::QuerySource;

    fn env_or(name: &str, default: u64) -> u64 {
        std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// One admin round trip, exactly as `parm admin` performs it: a
    /// fresh connection, one JSON line out, one JSON line back.
    fn admin(socket: &Path, req: Json) -> anyhow::Result<Json> {
        let mut stream = UnixStream::connect(socket)?;
        stream.write_all(req.to_string().as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        let mut reply = String::new();
        BufReader::new(stream).read_line(&mut reply)?;
        let parsed = Json::parse(reply.trim())?;
        anyhow::ensure!(
            parsed.at(&["ok"]).as_bool() == Some(true),
            "admin request {req} failed: {}",
            reply.trim()
        );
        Ok(parsed)
    }

    /// One Prometheus scrape, as any monitoring agent would take it.
    fn scrape(addr: SocketAddr) -> anyhow::Result<String> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(5)))?;
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n")?;
        let mut out = String::new();
        stream.read_to_string(&mut out)?;
        Ok(out)
    }

    /// Parity-pool re-provisioning is generational and asynchronous;
    /// poll `status` until size and target agree on `want`.
    fn wait_pool(socket: &Path, want: usize) -> anyhow::Result<()> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let st = admin(socket, Json::obj().set("cmd", "status"))?;
            if st.at(&["parity_pool", "size"]).as_usize() == Some(want)
                && st.at(&["parity_pool", "target"]).as_usize() == Some(want)
            {
                return Ok(());
            }
            anyhow::ensure!(Instant::now() < deadline, "parity pool never reached {want}: {st}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    pub fn run() -> anyhow::Result<()> {
        parm::util::logging::init();
        let clients = env_or("PARM_CLIENTS", 10).max(2) as usize;
        let per = env_or("PARM_QUERIES_PER_CLIENT", 90).max(20);
        let shards = env_or("PARM_SHARDS", 3).max(2) as usize;
        let k = 2usize;
        let m_per_shard = 2usize;
        let r_max = 2usize;
        let pool_for = |s: usize| ((s * m_per_shard + k - 1) / k).max(1);

        let manifest = Manifest::load_default()?;
        let ds = manifest.dataset(latency::LATENCY_DATASET)?;
        let source = QuerySource::from_dataset(&manifest, ds)?;
        let models = latency::load_models(&manifest, 1, k, r_max, false)?;

        let rate = 220.0;
        let per_rate = rate / clients as f64;
        let run_secs = per as f64 / per_rate;
        let scale_out_at = Duration::from_secs_f64(run_secs * 0.25);
        let kill_at = Duration::from_secs_f64(run_secs * 0.50);
        let scale_in_at = Duration::from_secs_f64(run_secs * 0.75);
        let victim = 1usize; // an ORIGINAL shard — the added one must outlive the fault

        let mut cfg = ServiceConfig::defaults(
            Mode::CrossShard { k, r_min: 1, r_max, halflife: Duration::from_millis(400) },
            &GPU,
        );
        cfg.m = m_per_shard;
        cfg.shuffles = 0;
        cfg.seed = 0xE1A57;
        cfg.slo = Some(Duration::from_secs(2));

        let tier = CrossShardFrontend::start(
            cfg,
            ShardSpec { shards, vnodes: 64, global_backlog: None },
            &models,
            &source.queries[0],
        )?;
        let plane = Arc::new(ControlPlane::new(Fleet::CrossShard(tier)));
        let socket =
            std::env::temp_dir().join(format!("parm-elastic-serve-{}.sock", std::process::id()));
        let server = AdminServer::bind(&socket, Arc::clone(&plane))?;
        // The operator-facing metrics pipe: the fleet's registry behind
        // a Prometheus endpoint, with the plane's scrape-time sampler
        // folding fresh shard/window state into every render.
        let registry = plane.registry();
        let sampler = plane.register_sampler();
        let exporter = Exporter::bind("127.0.0.1:0", registry.clone())?;
        let metrics_addr = exporter.local_addr();
        println!(
            "{clients} clients x {per} queries over {shards} shards at {rate:.0} qps; \
             admin endpoint at {}, metrics at http://{metrics_addr}/metrics",
            socket.display()
        );
        println!(
            "timeline: add-shard t={:.1}s | kill shard {victim} whole t={:.1}s | \
             drain+remove t={:.1}s\n",
            scale_out_at.as_secs_f64(),
            kill_at.as_secs_f64(),
            scale_in_at.as_secs_f64()
        );

        let start = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let client = plane.client().expect("fleet is live");
            let queries = source.queries.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Pcg64::new(0xE1A5EED ^ (c as u64) << 13);
                let mut due = Instant::now();
                let mut accepted = 0u64;
                for i in 0..per {
                    due += Duration::from_secs_f64(rng.exponential(per_rate));
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    if client.submit(queries[i as usize % queries.len()].clone()).is_ok() {
                        accepted += 1;
                    }
                    let _ = client.poll();
                }
                while client.stats().resolved < accepted {
                    if client.next(Duration::from_secs(8)).is_none() {
                        break;
                    }
                }
                client
            }));
        }

        let sleep_until = |at: Duration| {
            let now = start.elapsed();
            if at > now {
                std::thread::sleep(at - now);
            }
        };

        // --- operator timeline, entirely over the wire ---
        sleep_until(scale_out_at);
        let reply = admin(&socket, Json::obj().set("cmd", "add-shard"))?;
        let added = reply.at(&["shard"]).as_usize().expect("add-shard reply names the shard");
        wait_pool(&socket, pool_for(shards + 1))?;
        println!(
            "t={:.1}s: scaled OUT -> shard {added} joined the ring, parity pool at {}\n  {reply}",
            start.elapsed().as_secs_f64(),
            pool_for(shards + 1)
        );

        sleep_until(kill_at);
        for i in 0..m_per_shard {
            plane.kill_instance(victim, i)?;
        }
        println!(
            "t={:.1}s: killed EVERY instance of shard {victim} (undetected zombies)",
            start.elapsed().as_secs_f64()
        );
        std::thread::sleep(Duration::from_millis(600));
        let rec = admin(&socket, Json::obj().set("cmd", "recommend"))?;
        println!("t={:.1}s: recommend -> {rec}", start.elapsed().as_secs_f64());
        let scraped = scrape(metrics_addr)?;
        assert!(
            scraped.contains("parm_reconfig_total{verb=\"add_shard\"}"),
            "the scale-out verb must be on the endpoint by now"
        );
        println!(
            "t={:.1}s: /metrics mid-fault (selected families):",
            start.elapsed().as_secs_f64()
        );
        for line in scraped.lines().filter(|l| {
            l.starts_with("parm_shards{")
                || l.starts_with("parm_fleet_window_p99_ms")
                || l.starts_with("parm_reconfig_total")
                || l.starts_with("parm_parity_pool")
        }) {
            println!("    {line}");
        }

        sleep_until(scale_in_at);
        let drained = admin(&socket, Json::obj().set("cmd", "drain").set("shard", added))?;
        admin(&socket, Json::obj().set("cmd", "remove-shard").set("shard", added))?;
        wait_pool(&socket, pool_for(shards))?;
        println!(
            "t={:.1}s: scaled IN -> shard {added} drained ({drained}) and retired, \
             parity pool back at {}",
            start.elapsed().as_secs_f64(),
            pool_for(shards)
        );
        let status = admin(&socket, Json::obj().set("cmd", "status"))?;
        println!("  status -> {status}\n");

        println!(
            "{:<8} {:>6} {:>9} {:>9} {:>10} {:>10} {:>10} {:>9}",
            "client", "shard", "submitted", "resolved", "p50(ms)", "p99(ms)", "recovered", "default"
        );
        let mut joined = Vec::new();
        for j in joins {
            joined.push(j.join().expect("client thread"));
        }
        plane.flush_open_groups()?;
        let mut total_recovered = 0u64;
        let mut total_defaulted = 0u64;
        for client in &joined {
            let st = client.stats();
            let w = client.window();
            total_recovered += st.recovered;
            total_defaulted += st.defaulted;
            println!(
                "{:<8} {:>6} {:>9} {:>9} {:>10.3} {:>10.3} {:>10} {:>9}",
                client.id(),
                client.shard().map_or_else(|| "-".into(), |s| s.to_string()),
                st.submitted,
                st.resolved,
                w.p50_ms,
                w.p99_ms,
                st.recovered,
                st.defaulted,
            );
        }

        let telemetry = admin(&socket, Json::obj().set("cmd", "telemetry"))?;
        println!("\ntelemetry -> {telemetry}");
        // The admin view is computed from the same registry the
        // endpoint serves; spot-check they agree on resolved totals.
        let final_scrape = scrape(metrics_addr)?;
        assert!(
            final_scrape.contains("parm_fleet_window_resolved"),
            "merged fleet window must be on the endpoint"
        );

        registry.drop_sampler(sampler);
        exporter.shutdown();
        server.stop();
        let res = match plane.shutdown()? {
            FleetRunResult::CrossShard(res) => res,
            FleetRunResult::Sharded(_) => unreachable!("plane owns a cross-shard fleet"),
        };
        let t = &res.telemetry;
        println!(
            "\ncoding: groups={} parity_jobs={} reconstructions={}",
            t.groups_sealed, t.parity_jobs, t.reconstructions
        );
        let mut metrics = res.fleet.merged.metrics;
        println!("{}", metrics.report("fleet total"));
        let sum_resolved: u64 = res.fleet.per_shard.iter().map(|r| r.metrics.total()).sum();
        assert_eq!(metrics.total(), sum_resolved, "merged record equals per-shard sums");
        assert_eq!(
            res.fleet.per_shard.len(),
            shards + 1,
            "the retired shard still reports its run record"
        );
        println!(
            "\n✓ scale-out -> whole-shard kill -> scale-in, all over the admin socket: \
             {} reconstructions, {} recovered at clients, {} defaults",
            t.reconstructions, total_recovered, total_defaulted
        );
        if total_defaulted == 0 {
            println!("✓ zero queries lost to the SLO across the whole reconfiguration timeline");
        }
        Ok(())
    }
}

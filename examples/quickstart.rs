//! Quickstart: the whole ParM pipeline on one coding group, end to end.
//!
//! Paper scenario: Figure 2's single coding group — the paper's core
//! mechanism in isolation. One encode (§3.2), one parity inference on a
//! learned parity model (§3.3), one decode of a "lost" prediction (§3.2),
//! with no cluster, batching, or failure simulation around it.
//!
//! 1. load the AOT artifacts (deployed + parity model, k = 2),
//! 2. encode two real queries into a parity query (Rust encoder),
//! 3. run all three inferences via PJRT,
//! 4. pretend one prediction is lost and reconstruct it with the decoder,
//! 5. compare the reconstruction to the "lost" prediction.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use parm::artifacts::Manifest;
use parm::coordinator::{decoder, encoder::Encoder};
use parm::experiments::accuracy::run_all;
use parm::runtime::engine::Executable;
use parm::workload::QuerySource;

const DATASET: &str = "synthvision10";
const ARCH: &str = "microresnet";

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let dep_entry = m.deployed(DATASET, ARCH)?;
    let par_entry = m.parity(DATASET, ARCH, 2, "sum", 0)?;

    println!("loading deployed model {} …", dep_entry.name);
    let deployed = Executable::load(
        m.hlo_path(dep_entry, 1)?, &dep_entry.name, &dep_entry.input_shape, 1,
        dep_entry.out_dim,
    )?;
    println!("loading parity model {} …", par_entry.name);
    let parity = Executable::load(
        m.hlo_path(par_entry, 1)?, &par_entry.name, &par_entry.input_shape, 1,
        par_entry.out_dim,
    )?;

    let ds = m.dataset(DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let (x1, x2) = (&source.queries[0], &source.queries[1]);
    let (y1, y2) = (source.class_of(0).unwrap(), source.class_of(1).unwrap());

    // Encode: P = X1 + X2 (the paper's generic addition encoder).
    let enc = Encoder::sum(2);
    let t0 = std::time::Instant::now();
    let p = enc.encode(&[x1, x2])?;
    println!("encoded parity query in {:?}", t0.elapsed());

    // Inference on all three (normally three different servers).
    let f1 = run_all(&deployed, &[x1.clone()])?.remove(0);
    let f2 = run_all(&deployed, &[x2.clone()])?.remove(0);
    let fp = run_all(&parity, &[p])?.remove(0);

    // Suppose the second model instance is slow: reconstruct F(X2).
    let t0 = std::time::Instant::now();
    let rec = decoder::decode_r1(&[1.0, 1.0], &fp, &[Some(f1.clone()), None], 1)?;
    println!("decoded reconstruction in {:?}", t0.elapsed());

    println!("\nquery 1: true class {y1}, predicted {}", f1.argmax());
    println!("query 2: true class {y2}, predicted {} (actual prediction)", f2.argmax());
    println!("query 2: reconstructed prediction argmax {}", rec.argmax());
    let l2: f32 = rec
        .data()
        .iter()
        .zip(f2.data())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt();
    println!("reconstruction L2 distance from true prediction: {l2:.3}");
    if rec.argmax() == f2.argmax() {
        println!("\n✓ reconstruction recovers the unavailable prediction's class");
    } else {
        println!("\n(reconstruction differs for this pair — ParM is approximate; see Fig 6 for aggregate accuracy)");
    }
    Ok(())
}

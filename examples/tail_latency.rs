//! End-to-end serving demo (the repo's headline E2E driver): serve real
//! batched queries through the full threaded coordinator against the
//! simulated GPU cluster with background shuffles, for ParM and all three
//! baselines, and report median / p99 / p99.9 latency + throughput.
//!
//! Paper scenario: §5.1 / Figure 11 — open-loop Poisson traffic against a
//! cluster whose network is perturbed by background data shuffles, with
//! the paper's comparison set (no-redundancy floor, ParM k=2,
//! Equal-Resources, approximate backup). The claim being reproduced:
//! ParM trims the 99.9th-percentile tail toward the median where
//! resource-equalized baselines cannot, at equal offered rate.
//!
//! Run with: `cargo run --release --example tail_latency`
//! Knobs: PARM_BENCH_QUERIES (default 8000).

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::experiments::latency::{self, LatencyRow};
use parm::workload::QuerySource;

fn main() -> anyhow::Result<()> {
    parm::util::logging::init();
    let m = Manifest::load_default()?;
    let n: u64 = std::env::var("PARM_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8_000);

    let k = 2usize;
    let ds = m.dataset(latency::LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(&m, ds)?;
    let models = latency::load_models(&m, 1, k, 1, true)?;
    let mean = parm::coordinator::service::measure_service(
        &models.deployed,
        &parm::tensor::Tensor::batch(&[source.queries[0].clone()])?,
        20,
    );
    let capacity = GPU.default_m as f64 / mean.as_secs_f64();
    let rate = 0.55 * capacity;
    println!(
        "serving {n} queries at {rate:.0} qps (measured capacity {capacity:.0} qps, m={} + redundancy, 4 shuffles)\n",
        GPU.default_m
    );

    let mut rows = Vec::new();
    for (mode, label) in [
        (Mode::NoRedundancy, "no-redundancy (m only)"),
        (Mode::Parm { k, encoders: vec![Encoder::sum(k)] }, "parm (k=2)"),
        (Mode::EqualResources { k }, "equal-resources"),
        (Mode::ApproxBackup { k }, "approx-backup"),
    ] {
        let mut cfg = ServiceConfig::defaults(mode, &GPU);
        cfg.seed = 0xE2E;
        rows.push(latency::run_point(&cfg, &models, &source, n, rate, label)?);
    }

    println!("{}", LatencyRow::header());
    for r in &rows {
        println!("{}", r.line());
    }
    let parm = &rows[1];
    let er = &rows[2];
    println!(
        "\nParM p99.9 is {:.0}% {} Equal-Resources' at the same rate; tail-to-median gap {:.1}x vs {:.1}x.",
        ((er.p999_ms - parm.p999_ms) / er.p999_ms * 100.0).abs(),
        if parm.p999_ms < er.p999_ms { "below" } else { "above" },
        parm.p999_ms / parm.median_ms,
        er.p999_ms / er.median_ms,
    );
    println!("reconstructions used: {}", parm.reconstructions);
    Ok(())
}
